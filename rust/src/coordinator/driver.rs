//! Algorithm 1: `PenalizedLR-MR(X, Y, k, λs)`.
//!
//! ```text
//! map    : for each sample (x, y): key = fold(row); emit(key, stats(x,y))
//! combine: in-mapper merge (Emitter)                       [eq. 11–12, 15]
//! reduce : merge chunk statistics per fold                 [eq. 13–14]
//! cv     : for λ in grid, fold i: fit on total − s_i, score on s_i
//! final  : fit at λ_opt on all data, back-transform        [eq. 3–4]
//! ```
//!
//! Exactly **one** pass over the data happens (the map job); the CV phase
//! and final fit touch only k·(p+1)²/2 + (p+1) numbers per fold.
//!
//! With `FitConfig::gram_block` > 0 the reduce is keyed by `(fold, panel)`
//! and runs in **retire mode**: each key's merged panel leaves the engine
//! straight into a [`crate::store::PanelStore`]
//! ([`crate::mapreduce::run_job_retire`]) — unbounded in-memory by
//! default, or spill-to-disk under `FitConfig::store_budget_bytes` — and
//! the whole CV/solve phase streams panel-by-panel through the store
//! ([`FoldStore`]), with the (fold × λ) sweep running as a second
//! MapReduce job on the worker pool ([`crate::cv::cross_validate_store`]).
//! Leader-resident statistics are then O(d·b · panels-in-flight), not
//! O(k·d²) — and the fit output is bit-for-bit identical to the resident
//! packed and tiled paths at every budget.

use anyhow::Result;

use crate::config::FitConfig;
use crate::cv::{cross_validate, cross_validate_store, CvResult, FoldStats};
use crate::data::dataset::Dataset;
use crate::data::synth::{SynthSpec, SynthStream};
use crate::mapreduce::{run_job, run_job_retire, Emitter, FoldAssigner, JobMetrics, TaskCtx};
use crate::model::fitted::FittedModel;
use crate::solver::cd::solve_cd;
use crate::solver::path::{default_grid, lambda_grid};
use crate::solver::screen::{default_keep, embed_beta, rank_top_m, screen_top_m, ScreenReport};
use crate::stats::symm::tri_len;
use crate::stats::tiles::{StatPanel, TileLayout};
use crate::stats::{Scatter, SuffStats};
use crate::store::{FoldStore, MemStore, PanelStore, SpillStore};
use crate::trace;

/// Everything a fit returns: the model, the CV curve, and job accounting.
#[derive(Debug, Clone)]
pub struct FitReport {
    /// final model trained at λ_opt on all data, in original units
    pub model: FittedModel,
    /// the selected penalty parameter (= `model.lambda`)
    pub lambda_opt: f64,
    /// full CV curve (Algorithm 1's optional extra return value)
    pub cv: CvResult,
    /// λ grid used
    pub lambdas: Vec<f64>,
    /// metrics of the single map/reduce job (the one data pass), including
    /// the map/shuffle/reduce phase split of the parallel tree-reduce
    pub map_metrics: JobMetrics,
    /// rows per fold as realized by the random assignment
    pub fold_sizes: Vec<u64>,
    /// total data passes performed (always 1 — asserted in tests)
    pub data_passes: usize,
    /// in-sample goodness of fit, from statistics alone
    pub diagnostics: crate::model::Diagnostics,
    /// conservative peak of **co-resident** statistic bytes across the
    /// leader and the reducers — NOT the largest single allocation: all
    /// fold statistics held at once (the store's resident peak on the
    /// store path; (k+1) whole statistics on the resident paths), plus the
    /// per-key reducers' in-flight merge state, plus the solver working
    /// set (Gram(s), complement scratch / screened sub-statistics).
    /// Before this accounting the field reported only the largest single
    /// allocation, under-reporting exactly the O(k·d²) co-residency this
    /// PR removes.
    pub stat_peak_alloc_bytes: usize,
    /// peak bytes of merged fold statistics resident on the leader: the
    /// panel store's high-water mark on the tiled path (≤ max(budget, one
    /// panel) when `store_budget_bytes` > 0 — asserted in tests), or the
    /// (k+1) resident whole statistics on the packed path
    pub resident_stat_bytes_peak: usize,
    /// cumulative bytes the panel store spilled to disk (0 unbudgeted).
    /// These fit-wide spill counters are ≥ their `map_metrics` twins,
    /// which snapshot the same store at statistics-job end (pre-CV).
    pub spill_bytes: usize,
    /// panel loads from spill files across the whole fit
    pub spill_reads: usize,
    /// panel writes to spill files across the whole fit
    pub spill_writes: usize,
    /// background prefetch loads the panel store issued across the fit
    /// (0 unbudgeted or with `--no-prefetch`)
    pub prefetch_issued: usize,
    /// demand panel reads that found their panel already resident because
    /// readahead loaded it first
    pub prefetch_hits: usize,
    /// prefetched panels evicted or removed before any demand read — a
    /// spill read spent for nothing
    pub prefetch_wasted: usize,
    /// spill-file reads that needed the bounded second attempt across the
    /// whole fit (transient partial reads healed by the re-read; real
    /// corruption surfaces as a named error instead)
    pub read_retries: usize,
    /// SIS screening outcome when the `screen_auto` path engaged (p over
    /// the threshold); `None` for the exact full-p fit
    pub screened: Option<ScreenReport>,
}

impl FitReport {
    /// The store-activity lines of the fit rendering (spill traffic,
    /// prefetch outcome, read retries) — ONE helper shared by every
    /// frontend path (in-process and proc-mode fits render through the
    /// same `fit` subcommand), so the two runtimes can never drift apart.
    /// Lines for zero-valued counters are omitted.
    pub fn store_activity_lines(&self) -> Vec<String> {
        let mut lines = Vec::new();
        if self.spill_writes > 0 {
            lines.push(format!(
                "panel store spilled {} ({} writes, {} reads back)",
                crate::bench::fmt_bytes(self.spill_bytes),
                self.spill_writes,
                self.spill_reads,
            ));
        }
        if self.prefetch_issued > 0 {
            lines.push(format!(
                "panel prefetch: {} issued, {} demand hits, {} wasted",
                self.prefetch_issued, self.prefetch_hits, self.prefetch_wasted,
            ));
        }
        if self.read_retries > 0 {
            lines.push(format!(
                "spill read retries: {} transient partial read(s) healed by the bounded re-read",
                self.read_retries,
            ));
        }
        lines
    }

    /// Machine-readable dump for `fit --metrics-json`: selection outcome,
    /// job phase metrics (including the worker busy-time skew) and the
    /// store counters, rendered through [`crate::util::json`].
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        use std::collections::BTreeMap;
        fn num(v: f64) -> Value {
            Value::Num(v)
        }
        let m = &self.map_metrics;
        let mut job = BTreeMap::new();
        job.insert("real_s".to_string(), num(m.real_s));
        job.insert("map_s".to_string(), num(m.map_s));
        job.insert("shuffle_s".to_string(), num(m.shuffle_s));
        job.insert("reduce_s".to_string(), num(m.reduce_s));
        job.insert("records".to_string(), num(m.records as f64));
        job.insert("tasks_completed".to_string(), num(m.tasks_completed as f64));
        job.insert("attempts".to_string(), num(m.attempts as f64));
        job.insert("retries".to_string(), num(m.retries as f64));
        job.insert("attempts_max".to_string(), num(m.attempts_max as f64));
        job.insert("deadline_expirations".to_string(), num(m.deadline_expirations as f64));
        job.insert("heartbeats_missed".to_string(), num(m.heartbeats_missed as f64));
        job.insert("shuffle_payloads".to_string(), num(m.shuffle_payloads as f64));
        job.insert("shuffle_bytes".to_string(), num(m.shuffle_bytes as f64));
        job.insert("max_payload_bytes".to_string(), num(m.max_payload_bytes as f64));
        job.insert("combined_nodes".to_string(), num(m.combined_nodes as f64));
        job.insert("reduce_merges".to_string(), num(m.reduce_merges as f64));
        job.insert("panels_skipped".to_string(), num(m.panels_skipped as f64));
        job.insert("worker_skew".to_string(), num(m.worker_skew()));
        let mut store = BTreeMap::new();
        store.insert(
            "resident_stat_bytes_peak".to_string(),
            num(self.resident_stat_bytes_peak as f64),
        );
        store.insert("spill_bytes".to_string(), num(self.spill_bytes as f64));
        store.insert("spill_reads".to_string(), num(self.spill_reads as f64));
        store.insert("spill_writes".to_string(), num(self.spill_writes as f64));
        store.insert("prefetch_issued".to_string(), num(self.prefetch_issued as f64));
        store.insert("prefetch_hits".to_string(), num(self.prefetch_hits as f64));
        store.insert("prefetch_wasted".to_string(), num(self.prefetch_wasted as f64));
        store.insert("read_retries".to_string(), num(self.read_retries as f64));
        let d = &self.diagnostics;
        let mut diag = BTreeMap::new();
        diag.insert("mse".to_string(), num(d.mse));
        diag.insert("rmse".to_string(), num(d.rmse));
        diag.insert("r2".to_string(), num(d.r2));
        diag.insert("adj_r2".to_string(), num(d.adj_r2));
        diag.insert("df".to_string(), num(d.df as f64));
        let mut root = BTreeMap::new();
        root.insert("lambda_opt".to_string(), num(self.lambda_opt));
        root.insert("alpha".to_string(), num(self.model.alpha));
        root.insert(
            "nnz".to_string(),
            num(self.model.beta.iter().filter(|b| **b != 0.0).count() as f64),
        );
        root.insert("p".to_string(), num(self.model.beta.len() as f64));
        root.insert("n_lambdas".to_string(), num(self.lambdas.len() as f64));
        root.insert("data_passes".to_string(), num(self.data_passes as f64));
        root.insert(
            "fold_sizes".to_string(),
            Value::Arr(self.fold_sizes.iter().map(|&s| num(s as f64)).collect()),
        );
        root.insert(
            "stat_peak_alloc_bytes".to_string(),
            num(self.stat_peak_alloc_bytes as f64),
        );
        root.insert("job".to_string(), Value::Obj(job));
        root.insert("store".to_string(), Value::Obj(store));
        root.insert("diagnostics".to_string(), Value::Obj(diag));
        Value::Obj(root)
    }
}

/// Rows buffered per fold before a blocked flush into the statistics
/// (the §Perf mapper optimization: blocked centered-gram beats per-row
/// rank-1 updates, so the mapper buckets rows by fold and flushes blocks).
const FOLD_FLUSH_ROWS: usize = 1024;

/// Resident bytes of one whole fold statistic in payload terms:
/// count + weight + d-length mean + packed d-triangle, 8 bytes each.
fn stat_bytes(d: usize) -> usize {
    8 * (2 + d + tri_len(d))
}

/// Resident bytes of a standardized quadratic form of dimension p: the
/// Gram triangle (same total in packed or tiled storage) plus the
/// xty/scale/x_mean vectors and the (n, y_var, y_mean) scalars.
fn quad_bytes(p: usize) -> usize {
    8 * (tri_len(p) + 3 * p + 2)
}

/// The resource-accounting slice of a [`FitReport`].
struct Footprint {
    stat_peak_alloc_bytes: usize,
    resident_stat_bytes_peak: usize,
    spill_bytes: usize,
    spill_reads: usize,
    spill_writes: usize,
    prefetch_issued: usize,
    prefetch_hits: usize,
    prefetch_wasted: usize,
    read_retries: usize,
}

impl Footprint {
    /// Accounting for the resident paths (packed, or tiled statistics held
    /// whole in a [`FoldStats`]): all k folds + the total stay co-resident
    /// through the CV phase, alongside `work_bytes` of solver working set.
    fn resident(k: usize, p: usize, work_bytes: usize) -> Footprint {
        let resident = (k + 1) * stat_bytes(p + 1);
        Footprint {
            stat_peak_alloc_bytes: resident + work_bytes,
            resident_stat_bytes_peak: resident,
            spill_bytes: 0,
            spill_reads: 0,
            spill_writes: 0,
            prefetch_issued: 0,
            prefetch_hits: 0,
            prefetch_wasted: 0,
            read_retries: 0,
        }
    }

    /// Accounting for the store path: the store's own resident peak (the
    /// leader), the per-key reducers' in-flight peak, the O(d·b) streaming
    /// transients (total/part/scratch panel clones), and `work_bytes` of
    /// solver working set.
    fn store(store: &FoldStore, map_metrics: &JobMetrics, work_bytes: usize) -> Footprint {
        let sm = store.metrics();
        let d = store.p() + 1;
        let transient = 3 * 8 * (2 + d + store.layout().max_panel_len());
        Footprint {
            stat_peak_alloc_bytes: sm.resident_bytes_peak
                + map_metrics.reduce_resident_bytes_peak
                + transient
                + work_bytes,
            resident_stat_bytes_peak: sm.resident_bytes_peak,
            spill_bytes: sm.spill_bytes,
            spill_reads: sm.spill_reads,
            spill_writes: sm.spill_writes,
            prefetch_issued: sm.prefetch_issued,
            prefetch_hits: sm.prefetch_hits,
            prefetch_wasted: sm.prefetch_wasted,
            read_retries: sm.read_retries,
        }
    }
}

/// Per-task fold bucketing: rows land in per-fold buffers and flush into
/// [`SuffStats::push_rows`] in blocks.  Generic over the statistic
/// backing: with `gram_block > 0` the per-fold statistics are panel-tiled
/// ([`crate::stats::TiledSymMat`]) — the rank-1/rank-4 scatter writes
/// straight into per-panel scratch, so a mapper never holds a single
/// O(d²) allocation and emit moves the panels out without a triangle copy.
/// `pub(crate)` so the out-of-process worker ([`super::procjob`]) runs the
/// exact same bucketing/flush sequence as an in-process map task — the
/// per-fold statistics a task produces must be bit-identical in both
/// runtimes.
pub(crate) struct FoldAccumulator<'a, S: Scatter> {
    assigner: &'a FoldAssigner,
    bufx: Vec<Vec<f64>>,
    bufy: Vec<Vec<f64>>,
    stats: Vec<SuffStats<S>>,
    /// route flushes through the nonzero-aware scatter kernels
    /// ([`SuffStats::push_rows_sparse`]) — bit-identical to the dense
    /// flush, arithmetic proportional to the touched-column union
    sparse: bool,
}

impl<'a, S: Scatter> FoldAccumulator<'a, S> {
    /// `proto` fixes the statistic shape (p and, when tiled, the panel
    /// layout) every fold accumulator is cloned empty from.
    pub(crate) fn new(k: usize, p: usize, assigner: &'a FoldAssigner, proto: &SuffStats<S>) -> Self {
        FoldAccumulator {
            assigner,
            bufx: (0..k).map(|_| Vec::with_capacity(FOLD_FLUSH_ROWS * p)).collect(),
            bufy: (0..k).map(|_| Vec::with_capacity(FOLD_FLUSH_ROWS)).collect(),
            stats: (0..k).map(|_| proto.like_empty()).collect(),
            sparse: false,
        }
    }

    /// Select the sparse flush path (builder-style; defaults dense).
    pub(crate) fn with_sparse(mut self, on: bool) -> Self {
        self.sparse = on;
        self
    }

    #[inline]
    fn push_row(&mut self, row_id: u64, x: &[f64], y: f64) {
        let fold = self.assigner.fold_of(row_id);
        self.bufx[fold].extend_from_slice(x);
        self.bufy[fold].push(y);
        if self.bufy[fold].len() >= FOLD_FLUSH_ROWS {
            self.flush(fold);
        }
    }

    fn flush(&mut self, fold: usize) {
        if !self.bufy[fold].is_empty() {
            if self.sparse {
                self.stats[fold].push_rows_sparse(&self.bufx[fold], &self.bufy[fold]);
            } else {
                self.stats[fold].push_rows(&self.bufx[fold], &self.bufy[fold]);
            }
            self.bufx[fold].clear();
            self.bufy[fold].clear();
        }
    }

    /// Flush everything and hand back the non-empty per-fold statistics.
    pub(crate) fn finish(mut self) -> Vec<(usize, SuffStats<S>)> {
        for fold in 0..self.stats.len() {
            self.flush(fold);
        }
        self.stats
            .into_iter()
            .enumerate()
            .filter(|(_, s)| !s.is_empty())
            .collect()
    }
}

/// Row-feeding facade over [`FoldAccumulator`]: one ingestion closure (in-
/// memory blocks, synthetic streams, CSV shards) drives either statistic
/// backing through this object-safe surface.
pub(crate) trait RowSink {
    fn add(&mut self, row_id: u64, x: &[f64], y: f64);
}

/// Number of map splits of a streamed synthetic workload.
pub(crate) fn n_synth_splits(n: usize, split_rows: usize) -> usize {
    n.div_ceil(split_rows.max(1))
}

/// Derive split `idx` of a streamed synthetic workload: `split_rows` rows
/// per split, disjoint global row ranges, and a noise seed derived from the
/// split index so retried tasks regenerate identical rows.  Shared by the
/// in-process statistics job and the out-of-process worker
/// ([`super::procjob`]) — both runtimes MUST derive identical splits for
/// their statistics to be bit-identical.
pub(crate) fn synth_split(
    spec: &SynthSpec,
    split_rows: usize,
    idx: usize,
) -> Option<(SynthSpec, usize)> {
    let split_rows = split_rows.max(1);
    let offset = idx.checked_mul(split_rows)?;
    if offset >= spec.n {
        return None;
    }
    let mut sub = spec.clone();
    sub.n = split_rows.min(spec.n - offset);
    sub.seed = spec.seed ^ (0x9E37_79B9 + idx as u64).rotate_left(17);
    Some((sub, offset))
}

/// Stream one synthetic split's rows into the sink.  Regenerates the true
/// β of the PARENT spec: [`SynthStream`] derives β from `sub.seed`, which
/// the split derivation overrode — so the stream is built manually with
/// the parent β.
pub(crate) fn feed_synth_split(
    parent: &SynthSpec,
    sub: &SynthSpec,
    start: usize,
    acc: &mut dyn RowSink,
) {
    let p = parent.p;
    let mut stream = SynthStream::with_beta(sub, parent.true_beta());
    let mut row_id = start as u64;
    while let Some((xb, yb)) = stream.next_block(4096) {
        for (x, &y) in xb.chunks_exact(p).zip(yb) {
            acc.add(row_id, x, y);
            row_id += 1;
        }
    }
}

/// Stream one CSV shard's rows into the sink.  Row ids are
/// (shard index, local row) so the fold split is stable under retries and
/// across runtimes.  Panics on shard errors — both engines' unwind guards
/// convert the panic into a named task failure.
pub(crate) fn feed_csv_shard(
    p: usize,
    shard_idx: usize,
    path: &std::path::Path,
    acc: &mut dyn RowSink,
) {
    let mut local = 0u64;
    let (got_p, _rows) = crate::data::csv::stream_csv(path, 4096, |xb, yb| {
        for (x, &y) in xb.chunks_exact(p).zip(yb) {
            let row_id = ((shard_idx as u64) << 40) | local;
            acc.add(row_id, x, y);
            local += 1;
        }
    })
    .unwrap_or_else(|e| panic!("shard {path:?}: {e:#}"));
    assert_eq!(got_p, p, "shard {path:?} width {got_p} != expected {p}");
}

impl<S: Scatter> RowSink for FoldAccumulator<'_, S> {
    #[inline]
    fn add(&mut self, row_id: u64, x: &[f64], y: f64) {
        self.push_row(row_id, x, y);
    }
}

/// The statistics job's output in whichever form the config selected.
/// The fit path consumes this directly; the `compute_fold_stats*`
/// inspection APIs materialize/concatenate to packed.
enum StatsJob {
    /// untiled: whole fold statistics, resident
    Packed(FoldStats),
    /// tiled: merged panels retired into a panel store (in-memory or
    /// spill-to-disk per `FitConfig::store_budget_bytes`)
    Stored(FoldStore),
}

impl StatsJob {
    fn into_packed(self) -> Result<FoldStats> {
        match self {
            StatsJob::Packed(folds) => Ok(folds),
            StatsJob::Stored(store) => store.to_fold_stats()?.to_packed(),
        }
    }
}

/// The Algorithm 1 leader.
#[derive(Debug, Clone)]
pub struct Driver {
    cfg: FitConfig,
}

impl Driver {
    /// Create a driver; panics on invalid config (use
    /// [`FitConfig::validate`] for recoverable handling).
    pub fn new(cfg: FitConfig) -> Self {
        cfg.validate().expect("invalid FitConfig");
        // Pin the scatter kernel process-wide when the config forces one
        // (`Auto` leaves runtime detection / the PLRMR_KERNEL env override
        // in charge) — both paths produce bit-identical statistics, this
        // only selects which instruction sequence computes them.
        if cfg.kernel != crate::stats::simd::KernelMode::Auto {
            crate::stats::simd::set_kernel_override(cfg.kernel);
        }
        Driver { cfg }
    }

    pub fn config(&self) -> &FitConfig {
        &self.cfg
    }

    /// One statistics MapReduce job over any split source: `feed` streams
    /// a split's rows into the per-task [`FoldAccumulator`]; the job then
    /// ships the per-fold statistics either whole (one `fold` key each,
    /// the classic path) or — when `FitConfig::gram_block` > 0 — as
    /// row-block panels under `(fold, panel)` keys.  On the tiled path the
    /// mapper *accumulates* panel-native (no O(d²) allocation, rank-1
    /// scatter straight into per-panel scratch), emit *moves* each panel
    /// (no shard-time triangle copy), no shuffle payload or merge-tree
    /// slot ever exceeds O(d·b) bytes, and the reduce runs in **retire
    /// mode**: each `(fold, panel)` key is merged by an owning worker and
    /// retired straight into the panel store — the leader never
    /// accumulates the merged output map, and with a spill budget its
    /// resident statistics never exceed max(budget, one panel).  The
    /// paths are bit-for-bit identical: panel kernels are exact row
    /// restrictions of the untiled merge, and the per-key replay runs the
    /// same merges per key as the fixed tree (asserted in
    /// `tests/integration.rs`).
    fn run_stats_job<I: Sync>(
        &self,
        p: usize,
        splits: &[I],
        feed: impl Fn(&TaskCtx, &I, &mut dyn RowSink) + Sync,
    ) -> Result<(StatsJob, JobMetrics)> {
        let k = self.cfg.folds;
        let sparse = self.cfg.sparse;
        let assigner = FoldAssigner::new(k, self.cfg.seed);
        if self.cfg.gram_block == 0 {
            let proto = SuffStats::new(p);
            let out = run_job(
                &self.cfg.engine(),
                splits,
                |ctx: &TaskCtx, split, em: &mut Emitter<usize, SuffStats>| {
                    let mut acc =
                        FoldAccumulator::new(k, p, &assigner, &proto).with_sparse(sparse);
                    feed(ctx, split, &mut acc);
                    for (fold, stats) in acc.finish() {
                        let rows = stats.count();
                        em.emit_aggregated(fold, stats, rows);
                    }
                },
            )?;
            let (folds, metrics) = Self::assemble(k, p, out)?;
            Ok((StatsJob::Packed(folds), metrics))
        } else {
            let layout = TileLayout::new(p + 1, self.cfg.gram_block);
            let proto = SuffStats::new_tiled(p, self.cfg.gram_block);
            let backing: Box<dyn PanelStore> = if self.cfg.store_budget_bytes > 0 {
                Box::new(
                    SpillStore::new(self.cfg.store_budget_bytes)
                        .map_err(anyhow::Error::new)?
                        .with_prefetch(self.cfg.prefetch),
                )
            } else {
                Box::new(MemStore::new())
            };
            let mut fold_store = FoldStore::new(backing, k, p, layout);
            let mut metrics = run_job_retire(
                &self.cfg.engine(),
                splits,
                |ctx: &TaskCtx, split, em: &mut Emitter<(usize, usize), StatPanel>| {
                    let mut acc =
                        FoldAccumulator::new(k, p, &assigner, &proto).with_sparse(sparse);
                    feed(ctx, split, &mut acc);
                    for (fold, stats) in acc.finish() {
                        let rows = stats.count();
                        let mut panels = stats.into_panels();
                        // sparse ingest: all-+0.0 panels ship as O(d)
                        // zero markers — the shuffle never carries a
                        // triangle the data never touched
                        if sparse {
                            for panel in &mut panels {
                                panel.compress_zeros();
                            }
                        }
                        let mut panels = panels.into_iter();
                        // the head panel carries the fold's record
                        // accounting; the rest ship unaccounted (same rows,
                        // more keys)
                        if let Some(head) = panels.next() {
                            em.emit_aggregated((fold, head.panel), head, rows);
                        }
                        for panel in panels {
                            em.emit_unaccounted((fold, panel.panel), panel);
                        }
                    }
                },
                |(fold, panel): (usize, usize), value: StatPanel| {
                    fold_store.retire(fold, panel, value)
                },
            )?;
            // coverage/header validation + the per-panel total merge —
            // named errors, never silently-wrong statistics
            fold_store.seal()?;
            let sm = fold_store.metrics();
            metrics.resident_stat_bytes_peak = sm.resident_bytes_peak;
            metrics.spill_bytes = sm.spill_bytes;
            metrics.spill_reads = sm.spill_reads;
            metrics.spill_writes = sm.spill_writes;
            metrics.prefetch_issued = sm.prefetch_issued;
            metrics.prefetch_hits = sm.prefetch_hits;
            metrics.prefetch_wasted = sm.prefetch_wasted;
            metrics.read_retries = sm.read_retries;
            metrics.panels_skipped = fold_store.zero_panels();
            Ok((StatsJob::Stored(fold_store), metrics))
        }
    }

    /// The statistics job over an in-memory dataset, in whichever backing
    /// the config selects (the fit path consumes this directly).
    fn stats_job(&self, data: &Dataset) -> Result<(StatsJob, JobMetrics)> {
        if self.cfg.proc_workers > 0 {
            anyhow::bail!(
                "proc_workers cannot fit an in-memory dataset: worker processes \
                 do not share the leader's address space — use a streaming source \
                 (fit_stream / --synth) or shard files (fit_csv_shards / --csv)"
            );
        }
        let splits: Vec<crate::data::dataset::DataBlock<'_>> = data
            .blocks(self.cfg.split_rows)
            .collect();
        self.run_stats_job(data.p, &splits, |_ctx, block, acc| {
            for (i, (x, y)) in block.iter().enumerate() {
                acc.add((block.offset + i) as u64, x, y);
            }
        })
    }

    /// Map+reduce phase over an in-memory dataset: one pass, k fold
    /// statistics out — concatenated to the packed representation (the
    /// inspection/interop API; `fit` streams through the store instead).
    pub fn compute_fold_stats(&self, data: &Dataset) -> Result<(FoldStats, JobMetrics)> {
        let (job, metrics) = self.stats_job(data)?;
        Ok((job.into_packed()?, metrics))
    }

    /// The statistics job over a streaming synthetic source (backing per
    /// config; nothing materialized).  With `proc_workers` > 0 the splits
    /// run on supervised worker *processes* ([`super::procjob`]) — each
    /// worker re-derives its split from the same [`synth_split`] rule, so
    /// the statistics are bit-identical to the in-process pool's.
    fn stats_job_stream(&self, spec: &SynthSpec) -> Result<(StatsJob, JobMetrics)> {
        if self.cfg.proc_workers > 0 {
            let (store, metrics) = super::procjob::stats_synth_proc(&self.cfg, spec)?;
            return Ok((StatsJob::Stored(store), metrics));
        }
        let p = spec.p;
        // split specs: same ground-truth β (spec.seed), independent noise
        // streams (derived seeds), disjoint global row ranges.
        let splits: Vec<(SynthSpec, usize)> = (0..n_synth_splits(spec.n, self.cfg.split_rows))
            .map(|idx| synth_split(spec, self.cfg.split_rows, idx).expect("idx in range"))
            .collect();
        self.run_stats_job(p, &splits, |_ctx, (sub, start), acc| {
            feed_synth_split(spec, sub, *start, acc)
        })
    }

    /// Map+reduce phase over a *streaming* synthetic source: nothing is
    /// materialized; each task generates its own split deterministically.
    /// (Packed inspection API — `fit_stream` streams through the store.)
    pub fn compute_fold_stats_stream(
        &self,
        spec: &SynthSpec,
    ) -> Result<(FoldStats, JobMetrics)> {
        let (job, metrics) = self.stats_job_stream(spec)?;
        Ok((job.into_packed()?, metrics))
    }

    /// The statistics job over CSV shard files (backing per config).
    fn stats_job_csv(
        &self,
        p: usize,
        shards: &[std::path::PathBuf],
    ) -> Result<(StatsJob, JobMetrics)> {
        anyhow::ensure!(!shards.is_empty(), "no shard files given");
        if self.cfg.proc_workers > 0 {
            let (store, metrics) = super::procjob::stats_csv_proc(&self.cfg, p, shards)?;
            return Ok((StatsJob::Stored(store), metrics));
        }
        let splits: Vec<(usize, &std::path::PathBuf)> =
            shards.iter().enumerate().collect();
        self.run_stats_job(p, &splits, |_ctx, &(shard_idx, path), acc| {
            feed_csv_shard(p, shard_idx, path, acc)
        })
    }

    /// Map+reduce phase over CSV shard *files*: each task streams its own
    /// shard in O(block) memory — the HDFS-mapper access pattern.  Row ids
    /// for fold assignment are (shard index, local row), so the fold split
    /// is deterministic per shard set regardless of worker scheduling.
    /// (Packed inspection API — `fit_csv_shards` streams through the store.)
    pub fn compute_fold_stats_csv(
        &self,
        p: usize,
        shards: &[std::path::PathBuf],
    ) -> Result<(FoldStats, JobMetrics)> {
        let (job, metrics) = self.stats_job_csv(p, shards)?;
        Ok((job.into_packed()?, metrics))
    }

    /// Algorithm 1, end to end, streaming CSV shards from disk.
    pub fn fit_csv_shards(
        &self,
        p: usize,
        shards: &[std::path::PathBuf],
    ) -> Result<FitReport> {
        let ev0 = trace::enabled().then(trace::now_us);
        let (job, metrics) = self.stats_job_csv(p, shards)?;
        if let Some(start_us) = ev0 {
            trace::emit_span("driver", "stats-job", "map-reduce".into(), 0, start_us, metrics.records);
        }
        self.fit_job(job, metrics)
    }

    fn assemble(
        k: usize,
        p: usize,
        out: crate::mapreduce::JobOutput<usize, SuffStats>,
    ) -> Result<(FoldStats, JobMetrics)> {
        let mut folds: Vec<SuffStats> = (0..k).map(|_| SuffStats::new(p)).collect();
        for (fold, stats) in out.output {
            folds[fold] = stats;
        }
        Ok((FoldStats::new(folds)?, out.metrics))
    }

    /// CV + final fit on whichever form the statistics job produced —
    /// stored panels stream through the budgeted working set; resident
    /// packed statistics go through the generic path.
    fn fit_job(&self, job: StatsJob, metrics: JobMetrics) -> Result<FitReport> {
        if trace::enabled() {
            // which scatter microkernel this fit dispatches to (config mode
            // as the key; n = 1 when the SIMD path is actually active)
            trace::emit_instant(
                "kernel",
                "dispatch",
                self.cfg.kernel.as_str().to_string(),
                0,
                u64::from(crate::stats::simd::simd_active()),
            );
        }
        match job {
            StatsJob::Packed(folds) => self.select_and_fit(&folds, metrics),
            StatsJob::Stored(store) => self.select_and_fit_store(&store, metrics),
        }
    }

    /// Descending λ grid per config: an explicit `lambda_ratio` wins;
    /// otherwise delegate to [`default_grid`]'s glmnet-style auto rule on
    /// the (sub-)model's own dimensions — shared by the exact and
    /// screened paths, with the heuristic itself living in `solver::path`.
    fn lambda_grid_for<S: Scatter>(&self, q: &crate::stats::suffstats::QuadForm<S>) -> Vec<f64> {
        if self.cfg.lambda_ratio > 0.0 {
            lambda_grid(
                q.lambda_max(self.cfg.penalty.alpha),
                self.cfg.n_lambdas,
                self.cfg.lambda_ratio,
            )
        } else {
            default_grid(q, self.cfg.penalty, self.cfg.n_lambdas)
        }
    }

    /// Assemble the [`FitReport`] pieces every select path shares
    /// (fold sizes, diagnostics against the full statistics, the one-pass
    /// invariant, the co-resident footprint).
    fn finish_report<S: Scatter>(
        folds: &FoldStats<S>,
        cv: CvResult,
        lambdas: Vec<f64>,
        map_metrics: JobMetrics,
        model: FittedModel,
        footprint: Footprint,
        screened: Option<ScreenReport>,
    ) -> FitReport {
        let fold_sizes = (0..folds.k()).map(|i| folds.fold(i).count()).collect();
        let diagnostics = crate::model::diagnostics(folds.total(), &model);
        FitReport {
            lambda_opt: model.lambda,
            model,
            cv,
            lambdas,
            map_metrics,
            fold_sizes,
            data_passes: 1,
            diagnostics,
            stat_peak_alloc_bytes: footprint.stat_peak_alloc_bytes,
            resident_stat_bytes_peak: footprint.resident_stat_bytes_peak,
            spill_bytes: footprint.spill_bytes,
            spill_reads: footprint.spill_reads,
            spill_writes: footprint.spill_writes,
            prefetch_issued: footprint.prefetch_issued,
            prefetch_hits: footprint.prefetch_hits,
            prefetch_wasted: footprint.prefetch_wasted,
            read_retries: footprint.read_retries,
            screened,
        }
    }

    /// CV phase + final fit from *resident* fold statistics (no data
    /// access), generic over the statistic backing.  When
    /// `FitConfig::screen_auto` > 0 and p exceeds it, the driver screens
    /// first (SIS) and fits on the m×m sub-Gram gathered straight from the
    /// statistics instead.
    pub fn select_and_fit<S: Scatter>(
        &self,
        folds: &FoldStats<S>,
        map_metrics: JobMetrics,
    ) -> Result<FitReport> {
        if self.cfg.screen_auto > 0 && folds.p() > self.cfg.screen_auto {
            return self.select_and_fit_screened(folds, map_metrics);
        }
        let p = folds.p();
        let ev0 = trace::enabled().then(trace::now_us);
        let q_total = folds.total().quad_form();
        if let Some(start_us) = ev0 {
            trace::emit_span("driver", "standardize", "total".into(), 0, start_us, p as u64);
        }
        let lambdas = self.lambda_grid_for(&q_total);
        let ev0 = trace::enabled().then(trace::now_us);
        let cv = cross_validate(folds, self.cfg.penalty, &lambdas, self.cfg.cd)?;
        if let Some(start_us) = ev0 {
            trace::emit_span(
                "driver",
                "cv",
                format!("k{}", folds.k()),
                0,
                start_us,
                lambdas.len() as u64,
            );
        }
        // final fit at λ_opt on ALL data (see kfold.rs on the line-24 typo)
        let ev0 = trace::enabled().then(trace::now_us);
        let sol = solve_cd(&q_total, self.cfg.penalty, cv.lambda_opt, None, self.cfg.cd);
        if let Some(start_us) = ev0 {
            trace::emit_span(
                "driver",
                "final-solve",
                format!("l={:.6}", cv.lambda_opt),
                0,
                start_us,
                sol.sweeps as u64,
            );
        }
        let (alpha, beta) = q_total.to_original_scale(&sol.beta);
        let model = FittedModel {
            alpha,
            beta,
            lambda: cv.lambda_opt,
            penalty: self.cfg.penalty,
            n_train: folds.n(),
        };
        // working set: one complement scratch + q_total + the in-flight
        // per-fold Gram
        let footprint =
            Footprint::resident(folds.k(), p, stat_bytes(p + 1) + 2 * quad_bytes(p));
        Ok(Self::finish_report(
            folds,
            cv,
            lambdas,
            map_metrics,
            model,
            footprint,
            None,
        ))
    }

    /// The screen-then-fit path (paper §4): SIS with the screening run
    /// *inside* the cross-validation, so selection never sees held-out
    /// data.  For each fold i the predictors are ranked by |marginal
    /// correlation| on the TRAINING complement `total − s_i` alone
    /// (m = min(n/log n, `screen_auto`)), the (m+1)-dim sub-statistics of
    /// train and held-out fold are gathered entry-by-entry straight off
    /// the stored scatter (panel seams included — the full triangle is
    /// never assembled), and the warm-started λ path is scored on the
    /// held-out sub-statistics — exact, because screened-out coefficients
    /// are identically 0.  The final model screens once on the total
    /// statistics at λ_opt and embeds back into R^p.
    fn select_and_fit_screened<S: Scatter>(
        &self,
        folds: &FoldStats<S>,
        map_metrics: JobMetrics,
    ) -> Result<FitReport> {
        let p = folds.p();
        let k = folds.k();
        let m = default_keep(folds.n(), p).min(self.cfg.screen_auto);
        // λ grid from the total's screened sub-model (the final-fit scale)
        let total_report = screen_top_m(folds.total(), m)?;
        let q_total = folds.total().subset(&total_report.selected).quad_form();
        let lambdas = self.lambda_grid_for(&q_total);
        // per-fold screening + sweep: support chosen from the training
        // complement only (no selection leakage into the CV curve)
        let ev0 = trace::enabled().then(trace::now_us);
        let n_l = lambdas.len();
        let mut fold_err = vec![vec![0.0; k]; n_l];
        let mut nnz = vec![vec![0usize; k]; n_l];
        let mut train = folds.total().like_empty();
        for i in 0..k {
            folds.train_into(i, &mut train);
            let fold_report = screen_top_m(&train, m)?;
            let sub_train = train.subset(&fold_report.selected);
            let held = folds.fold(i).subset(&fold_report.selected);
            let q = sub_train.quad_form();
            let mut warm: Option<Vec<f64>> = None;
            for (li, &lam) in lambdas.iter().enumerate() {
                let sol = solve_cd(&q, self.cfg.penalty, lam, warm.as_deref(), self.cfg.cd);
                let (alpha, beta_sub) = q.to_original_scale(&sol.beta);
                fold_err[li][i] = held.mse(alpha, &beta_sub);
                nnz[li][i] = sol.n_active;
                warm = Some(sol.beta);
            }
        }
        if let Some(start_us) = ev0 {
            trace::emit_span("driver", "screen", format!("m{m}"), 0, start_us, k as u64);
        }
        let cv = crate::cv::select::summarize(&lambdas, fold_err, nnz)?;
        // final fit: screen on ALL data, solve at λ_opt, embed into R^p
        let sol = solve_cd(&q_total, self.cfg.penalty, cv.lambda_opt, None, self.cfg.cd);
        let (alpha, beta_sub) = q_total.to_original_scale(&sol.beta);
        let beta = embed_beta(p, &total_report.selected, &beta_sub);
        let model = FittedModel {
            alpha,
            beta,
            lambda: cv.lambda_opt,
            penalty: self.cfg.penalty,
            n_train: folds.n(),
        };
        // working set: complement scratch + the (m+1)-dim train/held
        // sub-statistics + q_total and the per-fold sub-Gram
        let work = stat_bytes(p + 1) + 2 * stat_bytes(m + 1) + 2 * quad_bytes(m);
        let footprint = Footprint::resident(k, p, work);
        Ok(Self::finish_report(
            folds,
            cv,
            lambdas,
            map_metrics,
            model,
            footprint,
            Some(total_report),
        ))
    }

    /// CV + final fit over a **panel-store** handle: fold complements,
    /// standardization, held-out scoring, screening subsets and the ridge
    /// Gram all stream panel-by-panel through the store's budgeted working
    /// set, and the (fold × λ) sweep runs as a MapReduce job on the worker
    /// pool ([`cross_validate_store`]).  Bit-for-bit identical to
    /// [`Driver::select_and_fit`] on the resident statistics (asserted in
    /// tests and `tests/integration.rs`).
    fn select_and_fit_store(
        &self,
        store: &FoldStore,
        map_metrics: JobMetrics,
    ) -> Result<FitReport> {
        if self.cfg.screen_auto > 0 && store.p() > self.cfg.screen_auto {
            return self.select_and_fit_screened_store(store, map_metrics);
        }
        let p = store.p();
        let ev0 = trace::enabled().then(trace::now_us);
        let q_total = store.quad_form_train(None)?;
        if let Some(start_us) = ev0 {
            trace::emit_span("driver", "standardize", "total".into(), 0, start_us, p as u64);
        }
        let lambdas = self.lambda_grid_for(&q_total);
        // with proc workers, the (fold × λ) sweep runs on the supervised
        // worker processes; the shared fold_errors_store makes the two
        // runtimes bit-identical (asserted in tests/proc_workers.rs)
        let ev0 = trace::enabled().then(trace::now_us);
        let cv = if self.cfg.proc_workers > 0 {
            super::procjob::cv_proc(&self.cfg, store, &lambdas)?
        } else {
            cross_validate_store(
                store,
                self.cfg.penalty,
                &lambdas,
                self.cfg.cd,
                &self.cfg.engine(),
            )?
        };
        if let Some(start_us) = ev0 {
            trace::emit_span(
                "driver",
                "cv",
                format!("k{}", store.k()),
                0,
                start_us,
                lambdas.len() as u64,
            );
        }
        let ev0 = trace::enabled().then(trace::now_us);
        let sol = solve_cd(&q_total, self.cfg.penalty, cv.lambda_opt, None, self.cfg.cd);
        if let Some(start_us) = ev0 {
            trace::emit_span(
                "driver",
                "final-solve",
                format!("l={:.6}", cv.lambda_opt),
                0,
                start_us,
                sol.sweeps as u64,
            );
        }
        let (alpha, beta) = q_total.to_original_scale(&sol.beta);
        let model = FittedModel {
            alpha,
            beta,
            lambda: cv.lambda_opt,
            penalty: self.cfg.penalty,
            n_train: store.n(),
        };
        // working set: q_total on the driver, plus up to min(workers, k)
        // per-fold Grams co-resident across the parallel CV tasks
        let concurrent = self.cfg.workers.max(1).min(store.k());
        let work = (1 + concurrent) * quad_bytes(p);
        self.finish_report_store(store, cv, lambdas, map_metrics, model, work, None)
    }

    /// The screen-then-fit path over a panel store: identical structure to
    /// [`Driver::select_and_fit_screened`], with the correlations and the
    /// (m+1)-dim sub-statistics gathered streaming off the panels
    /// ([`FoldStore::marginal_abs_corr`], [`FoldStore::subset_train`]) —
    /// the ranking and sweep arithmetic is shared
    /// ([`rank_top_m`], `cv::select::summarize`), so the two paths are
    /// bit-identical.
    ///
    /// Runs on the leader even under `proc_workers` > 0: the screened
    /// (m+1)-dim sub-statistics are gathered entry-by-entry off the
    /// leader's store and never ship anywhere — process supervision covers
    /// the statistics job and the exact full-p CV sweep.
    fn select_and_fit_screened_store(
        &self,
        store: &FoldStore,
        map_metrics: JobMetrics,
    ) -> Result<FitReport> {
        let p = store.p();
        let k = store.k();
        let m = default_keep(store.n(), p).min(self.cfg.screen_auto);
        let total_report = rank_top_m(store.marginal_abs_corr(None)?, m)?;
        let q_total = store.subset_train(None, &total_report.selected)?.quad_form();
        let lambdas = self.lambda_grid_for(&q_total);
        let ev0 = trace::enabled().then(trace::now_us);
        let n_l = lambdas.len();
        let mut fold_err = vec![vec![0.0; k]; n_l];
        let mut nnz = vec![vec![0usize; k]; n_l];
        for i in 0..k {
            let fold_report = rank_top_m(store.marginal_abs_corr(Some(i))?, m)?;
            let sub_train = store.subset_train(Some(i), &fold_report.selected)?;
            let held = store.subset_fold(i, &fold_report.selected)?;
            let q = sub_train.quad_form();
            let mut warm: Option<Vec<f64>> = None;
            for (li, &lam) in lambdas.iter().enumerate() {
                let sol = solve_cd(&q, self.cfg.penalty, lam, warm.as_deref(), self.cfg.cd);
                let (alpha, beta_sub) = q.to_original_scale(&sol.beta);
                fold_err[li][i] = held.mse(alpha, &beta_sub);
                nnz[li][i] = sol.n_active;
                warm = Some(sol.beta);
            }
        }
        if let Some(start_us) = ev0 {
            trace::emit_span("driver", "screen", format!("m{m}"), 0, start_us, k as u64);
        }
        let cv = crate::cv::select::summarize(&lambdas, fold_err, nnz)?;
        let sol = solve_cd(&q_total, self.cfg.penalty, cv.lambda_opt, None, self.cfg.cd);
        let (alpha, beta_sub) = q_total.to_original_scale(&sol.beta);
        let beta = embed_beta(p, &total_report.selected, &beta_sub);
        let model = FittedModel {
            alpha,
            beta,
            lambda: cv.lambda_opt,
            penalty: self.cfg.penalty,
            n_train: store.n(),
        };
        let work = 2 * stat_bytes(m + 1) + 2 * quad_bytes(m);
        self.finish_report_store(
            store,
            cv,
            lambdas,
            map_metrics,
            model,
            work,
            Some(total_report),
        )
    }

    /// [`Driver::finish_report`]'s streaming twin: fold sizes from the
    /// store's O(d) headers, diagnostics streamed off the total's panels,
    /// and the footprint taken from the store's (post-CV) accounting.
    #[allow(clippy::too_many_arguments)]
    fn finish_report_store(
        &self,
        store: &FoldStore,
        cv: CvResult,
        lambdas: Vec<f64>,
        map_metrics: JobMetrics,
        model: FittedModel,
        work_bytes: usize,
        screened: Option<ScreenReport>,
    ) -> Result<FitReport> {
        let fold_sizes = (0..store.k()).map(|i| store.fold_count(i)).collect();
        let diagnostics = store.diagnostics(&model)?;
        let footprint = Footprint::store(store, &map_metrics, work_bytes);
        Ok(FitReport {
            lambda_opt: model.lambda,
            model,
            cv,
            lambdas,
            map_metrics,
            fold_sizes,
            data_passes: 1,
            diagnostics,
            stat_peak_alloc_bytes: footprint.stat_peak_alloc_bytes,
            resident_stat_bytes_peak: footprint.resident_stat_bytes_peak,
            spill_bytes: footprint.spill_bytes,
            spill_reads: footprint.spill_reads,
            spill_writes: footprint.spill_writes,
            prefetch_issued: footprint.prefetch_issued,
            prefetch_hits: footprint.prefetch_hits,
            prefetch_wasted: footprint.prefetch_wasted,
            read_retries: footprint.read_retries,
            screened,
        })
    }

    /// Algorithm 1, end to end, over an in-memory dataset.
    pub fn fit(&self, data: &Dataset) -> Result<FitReport> {
        let ev0 = trace::enabled().then(trace::now_us);
        let (job, metrics) = self.stats_job(data)?;
        if let Some(start_us) = ev0 {
            trace::emit_span("driver", "stats-job", "map-reduce".into(), 0, start_us, metrics.records);
        }
        self.fit_job(job, metrics)
    }

    /// Algorithm 1, end to end, over a streaming synthetic source.
    pub fn fit_stream(&self, spec: &SynthSpec) -> Result<FitReport> {
        let ev0 = trace::enabled().then(trace::now_us);
        let (job, metrics) = self.stats_job_stream(spec)?;
        if let Some(start_us) = ev0 {
            trace::emit_span("driver", "stats-job", "map-reduce".into(), 0, start_us, metrics.records);
        }
        self.fit_job(job, metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::serial::serial_cd;
    use crate::data::synth::generate;
    use crate::mapreduce::FaultPlan;
    use crate::solver::penalty::Penalty;

    fn small_cfg() -> FitConfig {
        FitConfig {
            folds: 5,
            n_lambdas: 25,
            workers: 4,
            split_rows: 1000,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_recovers_sparse_truth() {
        let spec = SynthSpec::sparse_linear(8000, 10, 0.3, 42);
        let data = generate(&spec);
        let report = Driver::new(small_cfg()).fit(&data).unwrap();
        assert_eq!(report.data_passes, 1);
        assert_eq!(report.map_metrics.records, 8000);
        let truth = spec.true_beta();
        for j in 0..10 {
            if truth[j] != 0.0 {
                assert!(
                    (report.model.beta[j] - truth[j]).abs() < 0.25,
                    "beta[{j}]={} truth={}",
                    report.model.beta[j],
                    truth[j]
                );
            } else {
                assert!(report.model.beta[j].abs() < 0.15);
            }
        }
        assert!((report.model.alpha - spec.intercept).abs() < 0.3);
        // fold sizes roughly balanced
        let min = report.fold_sizes.iter().min().unwrap();
        let max = report.fold_sizes.iter().max().unwrap();
        assert!(*max as f64 / *min as f64 > 0.0 && (*max - *min) < 8000 / 5);
    }

    #[test]
    fn exact_vs_serial_oracle_at_same_lambda() {
        // the one-pass fit at λ must equal raw-data CD at λ (C2)
        let data = generate(&SynthSpec::sparse_linear(3000, 6, 0.4, 7));
        let driver = Driver::new(small_cfg());
        let (folds, m) = driver.compute_fold_stats(&data).unwrap();
        let report = driver.select_and_fit(&folds, m).unwrap();
        let (oracle, _) = serial_cd(&data, Penalty::lasso(), report.lambda_opt, 1e-12, 50_000);
        for j in 0..6 {
            assert!(
                (report.model.beta[j] - oracle.beta[j]).abs() < 1e-6,
                "j={j}: {} vs {}",
                report.model.beta[j],
                oracle.beta[j]
            );
        }
    }

    #[test]
    fn worker_count_does_not_change_the_answer() {
        let data = generate(&SynthSpec::sparse_linear(4000, 5, 0.4, 21));
        let r1 = Driver::new(FitConfig { workers: 1, ..small_cfg() })
            .fit(&data)
            .unwrap();
        let r8 = Driver::new(FitConfig { workers: 8, ..small_cfg() })
            .fit(&data)
            .unwrap();
        assert_eq!(r1.lambda_opt, r8.lambda_opt);
        for j in 0..5 {
            assert!((r1.model.beta[j] - r8.model.beta[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn crash_retries_do_not_change_the_answer() {
        let data = generate(&SynthSpec::sparse_linear(3000, 4, 0.5, 31));
        let clean = Driver::new(small_cfg()).fit(&data).unwrap();
        let chaotic = Driver::new(FitConfig {
            fault: FaultPlan::chaotic(0.35, 5),
            ..small_cfg()
        })
        .fit(&data)
        .unwrap();
        assert!(chaotic.map_metrics.retries > 0, "chaos must actually happen");
        assert_eq!(clean.lambda_opt, chaotic.lambda_opt);
        for j in 0..4 {
            assert_eq!(clean.model.beta[j], chaotic.model.beta[j]);
        }
    }

    #[test]
    fn streaming_fit_works_without_materializing() {
        let spec = SynthSpec::sparse_linear(50_000, 8, 0.25, 11);
        let report = Driver::new(FitConfig { split_rows: 8192, ..small_cfg() })
            .fit_stream(&spec)
            .unwrap();
        assert_eq!(report.map_metrics.records, 50_000);
        let truth = spec.true_beta();
        for j in 0..8 {
            if truth[j] != 0.0 {
                assert!(
                    (report.model.beta[j] - truth[j]).abs() < 0.2,
                    "beta[{j}]={} truth={}",
                    report.model.beta[j],
                    truth[j]
                );
            }
        }
    }

    #[test]
    fn phase_metrics_flow_through_the_report() {
        let data = generate(&SynthSpec::sparse_linear(4000, 5, 0.4, 3));
        let report = Driver::new(small_cfg()).fit(&data).unwrap();
        let m = &report.map_metrics;
        assert!(m.map_s > 0.0, "map timing must be recorded");
        assert!(
            m.map_s + m.shuffle_s + m.reduce_s <= m.real_s + 1e-9,
            "phases must partition the wallclock: {} + {} + {} vs {}",
            m.map_s,
            m.shuffle_s,
            m.reduce_s,
            m.real_s
        );
        assert!(m.shuffle_payloads > 0, "workers must hand payloads to the leader");
        // with worker-side combining on, the leader sees far fewer
        // payloads than tasks would imply only when tasks > workers; at
        // minimum the accounting must be self-consistent
        assert!(m.shuffle_payloads <= m.tasks_completed + m.combined_nodes);
    }

    #[test]
    fn tiled_stats_job_bit_identical_to_untiled_across_blocks() {
        // the tentpole invariant at driver level: for every block size the
        // tiled (fold, panel)-keyed job — now retiring into the panel
        // store — reassembles to the exact untiled fold statistics, and
        // the whole fit is unchanged bit for bit, while no per-key payload
        // exceeds the O(d·b) bound and the leader's co-resident accounting
        // reflects the store.
        let data = generate(&SynthSpec::sparse_linear(4000, 6, 0.4, 13));
        let d = 6 + 1;
        let k = 5;
        let base = small_cfg();
        let untiled = Driver::new(base).fit(&data).unwrap();
        // the co-resident accounting fix: the packed path holds all k
        // folds + the total resident (NOT just one triangle)
        assert_eq!(
            untiled.resident_stat_bytes_peak,
            (k + 1) * super::stat_bytes(d),
            "packed path co-residency = k folds + total"
        );
        assert_eq!(
            untiled.stat_peak_alloc_bytes,
            (k + 1) * super::stat_bytes(d) + super::stat_bytes(d) + 2 * super::quad_bytes(6),
        );
        assert_eq!(untiled.spill_writes, 0);
        for block in [1usize, 3, d, 100] {
            let cfg = FitConfig { gram_block: block, ..base };
            let report = Driver::new(cfg).fit(&data).unwrap();
            assert_eq!(report.lambda_opt, untiled.lambda_opt, "b={block}");
            assert_eq!(report.model.beta, untiled.model.beta, "b={block}");
            assert_eq!(report.cv.fold_err, untiled.cv.fold_err, "b={block}");
            assert_eq!(report.map_metrics.records, 4000, "head-panel accounting");
            let layout = crate::stats::tiles::TileLayout::new(d, block);
            let bound = std::mem::size_of::<(usize, usize)>()
                + 8 * (2 + d + layout.max_panel_len());
            assert!(
                report.map_metrics.max_payload_bytes <= bound,
                "b={block}: payload {} over bound {bound}",
                report.map_metrics.max_payload_bytes
            );
            // unbudgeted MemStore: every panel of every fold + the total
            // stays resident — the exact co-resident bytes, not a guess
            let per_fold = 8 * (layout.n_panels() * (2 + d) + crate::stats::symm::tri_len(d));
            assert_eq!(
                report.resident_stat_bytes_peak,
                (k + 1) * per_fold,
                "b={block}: MemStore resident accounting"
            );
            assert_eq!(report.spill_writes, 0, "unbudgeted path must not spill");
        }
    }

    #[test]
    fn store_budget_bounds_residency_without_changing_bits() {
        // one-panel budget: the fit output is bit-identical to the
        // unbudgeted tiled fit and the packed fit, while the leader's
        // resident statistics never exceed the budget and the spill path
        // actually exercises
        let data = generate(&SynthSpec::sparse_linear(4000, 6, 0.4, 13));
        let d = 6 + 1;
        let block = 3;
        let base = small_cfg();
        let packed = Driver::new(base).fit(&data).unwrap();
        let layout = crate::stats::tiles::TileLayout::new(d, block);
        let one_panel = 8 * (2 + d + layout.max_panel_len());
        for budget in [one_panel, 4 * one_panel] {
            let cfg = FitConfig {
                gram_block: block,
                store_budget_bytes: budget,
                ..base
            };
            let report = Driver::new(cfg).fit(&data).unwrap();
            assert_eq!(report.model.beta, packed.model.beta, "budget={budget}");
            assert_eq!(report.lambda_opt, packed.lambda_opt);
            assert_eq!(report.cv.fold_err, packed.cv.fold_err);
            assert!(
                report.resident_stat_bytes_peak <= budget,
                "budget={budget}: resident peak {} over budget",
                report.resident_stat_bytes_peak
            );
            assert!(report.spill_writes > 0, "budget={budget}: must spill");
            assert!(report.spill_reads > 0, "budget={budget}: CV must reload panels");
            assert!(report.spill_bytes > 0);
            // the budgeted co-resident peak sits far below the packed
            // path's (k+1) whole statistics
            assert!(
                report.resident_stat_bytes_peak < packed.resident_stat_bytes_peak,
                "{} !< {}",
                report.resident_stat_bytes_peak,
                packed.resident_stat_bytes_peak
            );
        }
    }

    #[test]
    fn screen_auto_engages_above_threshold_and_embeds_back() {
        let spec = SynthSpec::sparse_linear(3000, 30, 0.1, 77);
        let data = generate(&spec);
        let cfg = FitConfig { screen_auto: 16, ..small_cfg() };
        let report = Driver::new(cfg).fit(&data).unwrap();
        let s = report.screened.as_ref().expect("p=30 > 16 must screen");
        assert!(s.selected.len() <= 16);
        let truth = spec.true_beta();
        for j in 0..30 {
            if truth[j] != 0.0 {
                assert!(s.selected.contains(&j), "signal {j} screened out");
                assert!((report.model.beta[j] - truth[j]).abs() < 0.3, "beta[{j}]");
            }
            if !s.selected.contains(&j) {
                assert_eq!(report.model.beta[j], 0.0, "screened-out beta must be 0");
            }
        }
        // the screened fit is backing-independent: the store path gathers
        // the same sub-Gram through panel seams
        let tiled = Driver::new(FitConfig { gram_block: 4, ..cfg }).fit(&data).unwrap();
        assert_eq!(report.model.beta, tiled.model.beta);
        assert_eq!(report.lambda_opt, tiled.lambda_opt);
        // and under a one-panel budget, still bit-identical
        let layout = crate::stats::tiles::TileLayout::new(31, 4);
        let budgeted = Driver::new(FitConfig {
            gram_block: 4,
            store_budget_bytes: 8 * (2 + 31 + layout.max_panel_len()),
            ..cfg
        })
        .fit(&data)
        .unwrap();
        assert_eq!(report.model.beta, budgeted.model.beta);
        assert_eq!(report.lambda_opt, budgeted.lambda_opt);
        assert!(budgeted.spill_writes > 0);
        // under the threshold the exact full-p path runs
        let exact = Driver::new(FitConfig { screen_auto: 64, ..small_cfg() })
            .fit(&data)
            .unwrap();
        assert!(exact.screened.is_none());
    }

    #[test]
    fn tiled_streaming_path_matches_untiled() {
        // the tiled job is threaded through every ingestion path (they all
        // share run_stats_job), not just the in-memory one
        let spec = SynthSpec::sparse_linear(20_000, 5, 0.4, 19);
        let base = FitConfig { split_rows: 2048, ..small_cfg() };
        let a = Driver::new(base).fit_stream(&spec).unwrap();
        let b = Driver::new(FitConfig { gram_block: 2, ..base })
            .fit_stream(&spec)
            .unwrap();
        assert_eq!(a.lambda_opt, b.lambda_opt);
        assert_eq!(a.model.beta, b.model.beta);
    }

    #[test]
    fn screen_then_tiled_fit_keeps_the_signal() {
        // the envelope story: tiled statistics bound the reduce payloads,
        // then SIS screening fits the penalized model on the survivors'
        // sub-Gram — the same one-pass statistics serve both.
        use crate::solver::screen::fit_screened;
        let spec = SynthSpec::sparse_linear(4000, 40, 0.1, 23);
        let data = generate(&spec);
        let cfg = FitConfig { gram_block: 8, ..small_cfg() };
        let (folds, _) = Driver::new(cfg).compute_fold_stats(&data).unwrap();
        let (model, report) = fit_screened(
            folds.total(),
            Penalty::lasso(),
            0.05,
            Some(12),
            Default::default(),
        )
        .unwrap();
        let truth = spec.true_beta();
        for j in 0..40 {
            if truth[j] != 0.0 {
                assert!(
                    report.selected.contains(&j),
                    "signal {j} screened out: {:?}",
                    report.selected
                );
                assert!((model.beta[j] - truth[j]).abs() < 0.3, "beta[{j}]");
            }
        }
    }

    #[test]
    fn sparse_ingest_is_bit_identical_to_dense_across_the_matrix() {
        // the tentpole invariant at driver level: `FitConfig::sparse` only
        // changes the *order of work* (touched-column unions, marker
        // panels), never the bits — across backings, worker counts,
        // chaotic faults and store budgets.
        let spec = SynthSpec {
            x_density: 0.15,
            ..SynthSpec::sparse_linear(4000, 6, 0.4, 13)
        };
        let data = generate(&spec);
        let d = 6 + 1;
        let layout = crate::stats::tiles::TileLayout::new(d, 3);
        let one_panel = 8 * (2 + d + layout.max_panel_len());
        let base = small_cfg();
        for block in [0usize, 3] {
            for workers in [1usize, 4, 8] {
                for (fault, budget) in [
                    (FaultPlan::none(), 0usize),
                    (FaultPlan::chaotic(0.35, 5), 0),
                    (FaultPlan::none(), one_panel),
                ] {
                    if budget > 0 && block == 0 {
                        continue; // budgets require the tiled path
                    }
                    let cfg = FitConfig {
                        gram_block: block,
                        workers,
                        fault,
                        store_budget_bytes: budget,
                        ..base
                    };
                    let dense = Driver::new(cfg).fit(&data).unwrap();
                    let sparse = Driver::new(cfg.with_sparse(true)).fit(&data).unwrap();
                    let tag = format!("b={block} w={workers} budget={budget}");
                    assert_eq!(dense.lambda_opt, sparse.lambda_opt, "{tag}");
                    assert_eq!(dense.model.beta, sparse.model.beta, "{tag}");
                    assert_eq!(dense.cv.fold_err, sparse.cv.fold_err, "{tag}");
                    assert_eq!(dense.model.alpha, sparse.model.alpha, "{tag}");
                }
            }
        }
    }

    #[test]
    fn sparse_ingest_suppresses_empty_panels_and_shrinks_the_shuffle() {
        // structured sparsity: columns 3..6 identically zero → the panel
        // covering exactly those triangle rows is all-+0.0 in every task,
        // ships as an O(d) zero marker, survives the merge tree as a
        // marker (zero columns have zero means in every chunk) and is
        // counted once per fold at the store's retire boundary.
        let src = generate(&SynthSpec::sparse_linear(4000, 9, 0.4, 17));
        let mut x = src.x.clone();
        for r in 0..src.n() {
            for j in 3..6 {
                x[r * 9 + j] = 0.0;
            }
        }
        let data = Dataset::new(9, x, src.y.clone());
        let base = FitConfig { gram_block: 3, ..small_cfg() };
        let dense = Driver::new(base).fit(&data).unwrap();
        let sparse = Driver::new(base.with_sparse(true)).fit(&data).unwrap();
        assert_eq!(dense.model.beta, sparse.model.beta);
        assert_eq!(dense.lambda_opt, sparse.lambda_opt);
        assert_eq!(dense.map_metrics.panels_skipped, 0, "dense path never compresses");
        // d = 10, block = 3 → panel 1 spans triangle rows 3..6 — exactly
        // the zero columns — so each of the 5 folds retires one marker
        assert_eq!(sparse.map_metrics.panels_skipped, 5);
        assert!(
            sparse.map_metrics.shuffle_bytes < dense.map_metrics.shuffle_bytes,
            "markers must shrink the shuffle: {} !< {}",
            sparse.map_metrics.shuffle_bytes,
            dense.map_metrics.shuffle_bytes
        );
        assert_eq!(sparse.map_metrics.records, 4000, "accounting intact under markers");
    }

    #[test]
    fn cv_curve_has_interior_minimum_most_of_the_time() {
        let data = generate(&SynthSpec::sparse_linear(6000, 12, 0.25, 99));
        let report = Driver::new(small_cfg()).fit(&data).unwrap();
        assert!(report.cv.opt_index > 0, "λ_max should not be optimal");
        assert!(report.model.nnz() > 0);
    }
}
