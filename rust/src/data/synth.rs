//! Synthetic regression workloads — seeded, streaming, paper-shaped.
//!
//! The generators cover the regimes the paper's claims exercise:
//! * sparse ground-truth β (lasso's home turf, T2/F3),
//! * AR(1)-correlated designs (where shrinkage matters),
//! * heavy-tailed noise (robust CV selection),
//! * huge common offsets (the §2.1 numerical-robustness stressor, T4).
//!
//! [`SynthStream`] yields row-blocks on demand so the scaling experiments
//! can push through hundreds of millions of rows in O(block) memory —
//! the honest stand-in for "billions of observations on HDFS".

use crate::data::dataset::Dataset;
use crate::rng::Rng;

/// Ground-truth model + distributional knobs for a synthetic workload.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub n: usize,
    pub p: usize,
    /// fraction of nonzero coefficients in the true β
    pub density: f64,
    /// sd of the additive noise on y
    pub noise_sd: f64,
    /// AR(1) correlation between adjacent predictors (0 = independent)
    pub rho: f64,
    /// common offset added to every predictor (robustness stressor)
    pub x_offset: f64,
    /// per-column scale of predictors
    pub x_scale: f64,
    /// true intercept
    pub intercept: f64,
    /// heavy-tailed noise: Student-t degrees of freedom (None = Gaussian)
    pub t_df: Option<f64>,
    /// fraction of *predictor entries* kept nonzero (1.0 = dense design;
    /// below 1.0, each entry is zeroed independently after generation —
    /// the sparse-ingest workload knob, distinct from β's `density`)
    pub x_density: f64,
    pub seed: u64,
}

impl SynthSpec {
    /// Sparse linear model with unit-scale independent predictors.
    pub fn sparse_linear(n: usize, p: usize, density: f64, seed: u64) -> Self {
        SynthSpec {
            n,
            p,
            density,
            noise_sd: 1.0,
            rho: 0.0,
            x_offset: 0.0,
            x_scale: 1.0,
            intercept: 2.0,
            t_df: None,
            x_density: 1.0,
            seed,
        }
    }

    /// Correlated design (AR(1) with given ρ).
    pub fn correlated(n: usize, p: usize, rho: f64, seed: u64) -> Self {
        SynthSpec { rho, ..Self::sparse_linear(n, p, 0.2, seed) }
    }

    /// The T4 stressor: unit-variance signal riding a huge common offset.
    pub fn ill_conditioned(n: usize, p: usize, offset: f64, seed: u64) -> Self {
        SynthSpec { x_offset: offset, ..Self::sparse_linear(n, p, 0.3, seed) }
    }

    /// Draw the ground-truth β for this spec (deterministic in the seed).
    pub fn true_beta(&self) -> Vec<f64> {
        let mut rng = Rng::seed_from(self.seed ^ 0xBE7A);
        let k = ((self.p as f64 * self.density).round() as usize).clamp(1, self.p);
        let mut beta = vec![0.0; self.p];
        let mut idx: Vec<usize> = (0..self.p).collect();
        rng.shuffle(&mut idx);
        for &j in idx.iter().take(k) {
            // magnitudes in [0.5, 2.5], random sign — well above noise
            let mag = 0.5 + 2.0 * rng.uniform();
            beta[j] = if rng.coin(0.5) { mag } else { -mag };
        }
        beta
    }
}

/// A streaming row-block source: deterministic, restartable, O(block) memory.
pub struct SynthStream {
    spec: SynthSpec,
    beta: Vec<f64>,
    rng: Rng,
    emitted: usize,
    /// scratch latent variable for the AR(1) design
    xbuf: Vec<f64>,
    ybuf: Vec<f64>,
}

impl SynthStream {
    pub fn new(spec: &SynthSpec) -> Self {
        Self::with_beta(spec, spec.true_beta())
    }

    /// Stream with an explicitly provided ground-truth β — used when a
    /// parent workload is split across tasks: each split gets a derived
    /// noise seed but must share the parent's β.
    pub fn with_beta(spec: &SynthSpec, beta: impl Into<Vec<f64>>) -> Self {
        let beta = beta.into();
        assert_eq!(beta.len(), spec.p, "beta length must equal p");
        SynthStream {
            beta,
            rng: Rng::seed_from(spec.seed),
            spec: spec.clone(),
            emitted: 0,
            xbuf: Vec::new(),
            ybuf: Vec::new(),
        }
    }

    pub fn spec(&self) -> &SynthSpec {
        &self.spec
    }

    pub fn true_beta(&self) -> &[f64] {
        &self.beta
    }

    /// Rows remaining.
    pub fn remaining(&self) -> usize {
        self.spec.n - self.emitted
    }

    /// Fill the internal buffers with the next ≤ `block_rows` rows and
    /// return (x_block row-major, y_block).  Returns None when exhausted.
    pub fn next_block(&mut self, block_rows: usize) -> Option<(&[f64], &[f64])> {
        let take = block_rows.min(self.remaining());
        if take == 0 {
            return None;
        }
        let p = self.spec.p;
        self.xbuf.resize(take * p, 0.0);
        self.ybuf.resize(take, 0.0);
        let sqrho = (1.0 - self.spec.rho * self.spec.rho).sqrt();
        for r in 0..take {
            let row = &mut self.xbuf[r * p..(r + 1) * p];
            let mut prev = 0.0;
            for j in 0..p {
                let z = if j == 0 || self.spec.rho == 0.0 {
                    self.rng.normal()
                } else {
                    self.spec.rho * prev + sqrho * self.rng.normal()
                };
                prev = z;
                row[j] = self.spec.x_offset + self.spec.x_scale * z;
                // sparse design: mask entries *after* the latent AR(1)
                // draw so the chain (and every dense stream at
                // x_density = 1.0, which draws no extra variates) is
                // bit-stable across density settings
                if self.spec.x_density < 1.0 && self.rng.uniform() >= self.spec.x_density {
                    row[j] = 0.0;
                }
            }
            let noise = match self.spec.t_df {
                Some(df) => self.rng.student_t(df),
                None => self.rng.normal(),
            } * self.spec.noise_sd;
            // y depends on the *centered/scaled* signal so that β stays the
            // true coefficient in original units.
            let mut acc = self.spec.intercept + noise;
            for j in 0..p {
                acc += row[j] * self.beta[j];
            }
            self.ybuf[r] = acc;
        }
        self.emitted += take;
        Some((&self.xbuf[..], &self.ybuf[..]))
    }
}

/// Materialize a full dataset from a spec (small/medium n only).
pub fn generate(spec: &SynthSpec) -> Dataset {
    let mut stream = SynthStream::new(spec);
    let mut x = Vec::with_capacity(spec.n * spec.p);
    let mut y = Vec::with_capacity(spec.n);
    while let Some((xb, yb)) = stream.next_block(8192) {
        x.extend_from_slice(xb);
        y.extend_from_slice(yb);
    }
    Dataset::new(spec.p, x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::SuffStats;

    #[test]
    fn deterministic_and_streaming_equals_materialized() {
        let spec = SynthSpec::sparse_linear(1000, 5, 0.4, 7);
        let d1 = generate(&spec);
        let d2 = generate(&spec);
        assert_eq!(d1, d2);
        // streaming in odd block sizes gives the same rows
        let mut s = SynthStream::new(&spec);
        let mut x = Vec::new();
        let mut y = Vec::new();
        while let Some((xb, yb)) = s.next_block(333) {
            x.extend_from_slice(xb);
            y.extend_from_slice(yb);
        }
        assert_eq!(x, d1.x);
        assert_eq!(y, d1.y);
    }

    #[test]
    fn true_beta_density() {
        let spec = SynthSpec::sparse_linear(10, 100, 0.1, 3);
        let beta = spec.true_beta();
        let nnz = beta.iter().filter(|b| **b != 0.0).count();
        assert_eq!(nnz, 10);
        assert!(beta.iter().all(|b| b.abs() == 0.0 || (0.5..=2.5).contains(&b.abs())));
        // deterministic
        assert_eq!(beta, spec.true_beta());
    }

    #[test]
    fn generated_data_follows_model() {
        // OLS on generated data should recover beta within noise.
        let spec = SynthSpec::sparse_linear(20_000, 4, 0.5, 11);
        let d = generate(&spec);
        let beta = spec.true_beta();
        let mse_truth = d.mse(spec.intercept, &beta);
        // residual variance ≈ noise_sd²
        assert!((mse_truth - 1.0).abs() < 0.1, "mse={mse_truth}");
    }

    #[test]
    fn ar1_correlation_structure() {
        let spec = SynthSpec::correlated(30_000, 3, 0.8, 13);
        let d = generate(&spec);
        let mut s = SuffStats::new(3);
        for i in 0..d.n() {
            s.push(d.row(i), d.y[i]);
        }
        let q = s.quad_form();
        // adjacent correlation ≈ 0.8, two-step ≈ 0.64
        assert!((q.gram.get(0, 1) - 0.8).abs() < 0.02, "r01={}", q.gram.get(0, 1));
        assert!((q.gram.get(0, 2) - 0.64).abs() < 0.03, "r02={}", q.gram.get(0, 2));
    }

    #[test]
    fn offset_moves_means_not_variance() {
        let spec = SynthSpec::ill_conditioned(5000, 2, 1e7, 17);
        let d = generate(&spec);
        let mut s = SuffStats::new(2);
        for i in 0..d.n() {
            s.push(d.row(i), d.y[i]);
        }
        assert!((s.x_mean()[0] - 1e7).abs() < 1e3);
        let var = s.sxx(0, 0) / s.count() as f64;
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn heavy_tail_spec_runs() {
        let spec = SynthSpec {
            t_df: Some(3.0),
            ..SynthSpec::sparse_linear(2000, 3, 0.5, 19)
        };
        let d = generate(&spec);
        assert_eq!(d.n(), 2000);
        assert!(d.y.iter().all(|y| y.is_finite()));
    }

    #[test]
    fn x_density_masks_entries_without_disturbing_dense_streams() {
        let dense_spec = SynthSpec::sparse_linear(2000, 8, 0.5, 23);
        let sparse_spec = SynthSpec { x_density: 0.1, ..dense_spec.clone() };
        let dd = generate(&dense_spec);
        let ds = generate(&sparse_spec);
        // every surviving entry matches the dense stream bitwise (the mask
        // draws extra variates, so rows diverge *after* the first masked
        // entry — check only the first column of each row, drawn first)
        let nnz = ds.x.iter().filter(|v| **v != 0.0).count();
        let frac = nnz as f64 / ds.x.len() as f64;
        assert!((frac - 0.1).abs() < 0.02, "frac={frac}");
        // y still follows the model on the masked design
        let beta = sparse_spec.true_beta();
        let mse = ds.mse(sparse_spec.intercept, &beta);
        assert!((mse - 1.0).abs() < 0.15, "mse={mse}");
        // x_density = 1.0 is exactly the historical stream
        let again = generate(&SynthSpec { x_density: 1.0, ..dense_spec.clone() });
        assert_eq!(again, dd);
        // deterministic
        assert_eq!(generate(&sparse_spec), ds);
    }

    #[test]
    fn remaining_countdown() {
        let spec = SynthSpec::sparse_linear(10, 2, 0.5, 1);
        let mut s = SynthStream::new(&spec);
        assert_eq!(s.remaining(), 10);
        let (xb, yb) = s.next_block(4).unwrap();
        assert_eq!((xb.len(), yb.len()), (8, 4));
        assert_eq!(s.remaining(), 6);
        s.next_block(100).unwrap();
        assert_eq!(s.remaining(), 0);
        assert!(s.next_block(4).is_none());
    }
}
