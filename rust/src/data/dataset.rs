//! Materialized datasets and row-block views.

/// A dense row-major design matrix plus response, fully in memory.
///
/// Used for exactness checks and small/medium experiments; large-n runs use
/// [`super::synth::SynthStream`] instead and never materialize.
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    /// number of predictors
    pub p: usize,
    /// row-major n×p
    pub x: Vec<f64>,
    /// length n
    pub y: Vec<f64>,
}

/// A borrowed block of rows (the unit the engine maps over).
#[derive(Debug, Clone, Copy)]
pub struct DataBlock<'a> {
    pub p: usize,
    /// row-major rows×p
    pub x: &'a [f64],
    pub y: &'a [f64],
    /// index of the first row within the parent dataset/stream
    pub offset: usize,
}

impl Dataset {
    pub fn new(p: usize, x: Vec<f64>, y: Vec<f64>) -> Self {
        assert_eq!(x.len(), y.len() * p, "x must be n*p, y length n");
        Dataset { p, x, y }
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// Row view.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.x[i * self.p..(i + 1) * self.p]
    }

    /// Iterate fixed-size blocks (last one may be short).
    pub fn blocks(&self, block_rows: usize) -> impl Iterator<Item = DataBlock<'_>> {
        assert!(block_rows > 0);
        let p = self.p;
        let n = self.n();
        (0..n.div_ceil(block_rows)).map(move |b| {
            let lo = b * block_rows;
            let hi = ((b + 1) * block_rows).min(n);
            DataBlock {
                p,
                x: &self.x[lo * p..hi * p],
                y: &self.y[lo..hi],
                offset: lo,
            }
        })
    }

    /// Split into `k` contiguous shards of near-equal size (for the engine's
    /// input splits; fold assignment is *random per record*, per Algorithm 1
    /// line 4 — sharding is independent of folds).
    pub fn shards(&self, k: usize) -> Vec<DataBlock<'_>> {
        assert!(k > 0);
        let n = self.n();
        let base = n / k;
        let extra = n % k;
        let mut out = Vec::with_capacity(k);
        let mut lo = 0;
        for i in 0..k {
            let len = base + usize::from(i < extra);
            let hi = lo + len;
            out.push(DataBlock {
                p: self.p,
                x: &self.x[lo * self.p..hi * self.p],
                y: &self.y[lo..hi],
                offset: lo,
            });
            lo = hi;
        }
        out
    }

    /// Predict with an original-scale model, appending into `out`.
    pub fn predict_into(&self, alpha: f64, beta: &[f64], out: &mut Vec<f64>) {
        assert_eq!(beta.len(), self.p);
        out.clear();
        out.reserve(self.n());
        for i in 0..self.n() {
            let row = self.row(i);
            let mut acc = alpha;
            for j in 0..self.p {
                acc += row[j] * beta[j];
            }
            out.push(acc);
        }
    }

    /// In-sample MSE of a model (direct two-pass computation — the oracle
    /// the suffstats-based [`crate::stats::SuffStats::mse`] is tested against).
    pub fn mse(&self, alpha: f64, beta: &[f64]) -> f64 {
        let mut preds = Vec::new();
        self.predict_into(alpha, beta, &mut preds);
        let n = self.n() as f64;
        preds
            .iter()
            .zip(&self.y)
            .map(|(p, y)| (y - p) * (y - p))
            .sum::<f64>()
            / n
    }
}

impl<'a> DataBlock<'a> {
    pub fn rows(&self) -> usize {
        self.y.len()
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.x[i * self.p..(i + 1) * self.p]
    }

    /// Iterate (row, y) pairs.
    pub fn iter(&self) -> impl Iterator<Item = (&'a [f64], f64)> + '_ {
        let p = self.p;
        self.y
            .iter()
            .enumerate()
            .map(move |(i, &y)| (&self.x[i * p..(i + 1) * p], y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new(
            2,
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0],
            vec![10.0, 20.0, 30.0, 40.0, 50.0],
        )
    }

    #[test]
    fn rows_and_blocks() {
        let d = tiny();
        assert_eq!(d.n(), 5);
        assert_eq!(d.row(2), &[5.0, 6.0]);
        let blocks: Vec<_> = d.blocks(2).collect();
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks[0].rows(), 2);
        assert_eq!(blocks[2].rows(), 1); // short tail
        assert_eq!(blocks[2].offset, 4);
        assert_eq!(blocks[1].row(1), &[7.0, 8.0]);
        let total: usize = blocks.iter().map(|b| b.rows()).sum();
        assert_eq!(total, 5);
    }

    #[test]
    fn shards_cover_everything() {
        let d = tiny();
        for k in 1..=5 {
            let shards = d.shards(k);
            assert_eq!(shards.len(), k);
            let total: usize = shards.iter().map(|s| s.rows()).sum();
            assert_eq!(total, 5, "k={k}");
            // sizes differ by at most 1
            let min = shards.iter().map(|s| s.rows()).min().unwrap();
            let max = shards.iter().map(|s| s.rows()).max().unwrap();
            assert!(max - min <= 1);
        }
    }

    #[test]
    fn block_iter_pairs() {
        let d = tiny();
        let b = d.blocks(5).next().unwrap();
        let pairs: Vec<_> = b.iter().collect();
        assert_eq!(pairs.len(), 5);
        assert_eq!(pairs[3], (&[7.0, 8.0][..], 40.0));
    }

    #[test]
    fn predict_and_mse() {
        let d = tiny();
        // y = 10 * x0 / 1 ... actually y = 10*((x0+1)/2) = 5*x0+5
        let mse = d.mse(5.0, &[5.0, 0.0]);
        assert!(mse < 1e-24, "mse={mse}");
        let mse_bad = d.mse(0.0, &[0.0, 0.0]);
        assert!(mse_bad > 100.0);
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        Dataset::new(2, vec![1.0, 2.0, 3.0], vec![1.0]);
    }
}
