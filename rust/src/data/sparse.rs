//! Sparse-row (CSR) representation for the ingest path.
//!
//! The workloads the ROADMAP targets (one-hot users/items, n-gram
//! features) are overwhelmingly sparse, and the sparse scatter kernels
//! ([`crate::stats::Scatter::rank1_sparse`]) only pay for the columns a
//! chunk actually touches.  This module is the validated front door: a
//! [`SparseRow`] is `y` plus strictly-ascending `(index, value)` pairs,
//! a [`CsrBlock`] is the standard indptr/indices/values block form, and
//! every malformed input (unsorted, duplicate, out-of-range index) maps
//! to a named [`SparseRowError`] — never a silent mis-scatter.

use std::fmt;

/// Named validation failures for sparse row input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SparseRowError {
    /// Indices must be strictly ascending; `next` followed `prev`.
    UnsortedIndex { prev: usize, next: usize },
    /// The same column appeared twice in one row.
    DuplicateIndex { index: usize },
    /// A column index at or beyond the declared width `p`.
    IndexOutOfRange { index: usize, p: usize },
}

impl fmt::Display for SparseRowError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SparseRowError::UnsortedIndex { prev, next } => {
                write!(f, "unsorted sparse index: {next} after {prev}")
            }
            SparseRowError::DuplicateIndex { index } => {
                write!(f, "duplicate sparse index {index}")
            }
            SparseRowError::IndexOutOfRange { index, p } => {
                write!(f, "sparse index {index} out of range for p={p}")
            }
        }
    }
}

impl std::error::Error for SparseRowError {}

/// Check one row's index list against the contract the scatter kernels
/// assume: strictly ascending, unique, all below `p`.
pub fn validate_indices(idx: &[usize], p: usize) -> Result<(), SparseRowError> {
    let mut prev: Option<usize> = None;
    for &j in idx {
        if j >= p {
            return Err(SparseRowError::IndexOutOfRange { index: j, p });
        }
        if let Some(q) = prev {
            if j == q {
                return Err(SparseRowError::DuplicateIndex { index: j });
            }
            if j < q {
                return Err(SparseRowError::UnsortedIndex { prev: q, next: j });
            }
        }
        prev = Some(j);
    }
    Ok(())
}

/// One validated sparse observation: response `y` plus the row's nonzero
/// `(index, value)` pairs in strictly ascending index order.  An empty
/// index list is a legal all-zero row.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseRow {
    pub y: f64,
    pub idx: Vec<usize>,
    pub vals: Vec<f64>,
}

impl SparseRow {
    /// Build a row, validating the indices against width `p`.
    pub fn new(y: f64, idx: Vec<usize>, vals: Vec<f64>, p: usize) -> Result<Self, SparseRowError> {
        assert_eq!(idx.len(), vals.len(), "index/value length mismatch");
        validate_indices(&idx, p)?;
        Ok(SparseRow { y, idx, vals })
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Scatter into a dense row buffer (`out.len()` = p), zeroing the rest.
    pub fn densify_into(&self, out: &mut [f64]) {
        out.fill(0.0);
        for (&j, &v) in self.idx.iter().zip(&self.vals) {
            out[j] = v;
        }
    }
}

/// Compressed sparse rows: the block form the sparse CSV reader and the
/// synth generator accumulate into before handing dense row-blocks to the
/// accumulators.  Row `r`'s pairs live at `indptr[r]..indptr[r+1]`.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrBlock {
    p: usize,
    pub y: Vec<f64>,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f64>,
}

impl CsrBlock {
    pub fn new(p: usize) -> Self {
        CsrBlock { p, y: Vec::new(), indptr: vec![0], indices: Vec::new(), values: Vec::new() }
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Append one row after validating its indices.
    pub fn push_row(&mut self, y: f64, idx: &[usize], vals: &[f64]) -> Result<(), SparseRowError> {
        assert_eq!(idx.len(), vals.len(), "index/value length mismatch");
        validate_indices(idx, self.p)?;
        self.indices.extend_from_slice(idx);
        self.values.extend_from_slice(vals);
        self.indptr.push(self.indices.len());
        self.y.push(y);
        Ok(())
    }

    /// Row `r` as (indices, values, y).
    pub fn row(&self, r: usize) -> (&[usize], &[f64], f64) {
        let span = self.indptr[r]..self.indptr[r + 1];
        (&self.indices[span.clone()], &self.values[span], self.y[r])
    }

    /// Materialize as a dense row-major (x, y) pair.
    pub fn to_dense(&self) -> (Vec<f64>, Vec<f64>) {
        let mut x = vec![0.0; self.n() * self.p];
        for r in 0..self.n() {
            let (idx, vals, _) = self.row(r);
            let out = &mut x[r * self.p..(r + 1) * self.p];
            for (&j, &v) in idx.iter().zip(vals) {
                out[j] = v;
            }
        }
        (x, self.y.clone())
    }

    /// Drop all rows, keeping the allocations (the streaming reader's
    /// per-block reuse).
    pub fn clear(&mut self) {
        self.y.clear();
        self.indptr.clear();
        self.indptr.push(0);
        self.indices.clear();
        self.values.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_rows_round_trip_through_densify() {
        let row = SparseRow::new(2.5, vec![1, 4], vec![-3.0, 7.0], 6).unwrap();
        assert_eq!(row.nnz(), 2);
        let mut buf = vec![9.9; 6];
        row.densify_into(&mut buf);
        assert_eq!(buf, vec![0.0, -3.0, 0.0, 0.0, 7.0, 0.0]);
    }

    #[test]
    fn all_zero_row_is_legal() {
        let row = SparseRow::new(1.0, vec![], vec![], 4).unwrap();
        assert_eq!(row.nnz(), 0);
        let mut block = CsrBlock::new(4);
        block.push_row(1.0, &[], &[]).unwrap();
        block.push_row(2.0, &[3], &[5.0]).unwrap();
        let (x, y) = block.to_dense();
        assert_eq!(x, vec![0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 5.0]);
        assert_eq!(y, vec![1.0, 2.0]);
    }

    #[test]
    fn named_errors_for_each_malformation() {
        assert_eq!(
            SparseRow::new(0.0, vec![3, 1], vec![1.0, 2.0], 5).unwrap_err(),
            SparseRowError::UnsortedIndex { prev: 3, next: 1 }
        );
        assert_eq!(
            SparseRow::new(0.0, vec![2, 2], vec![1.0, 2.0], 5).unwrap_err(),
            SparseRowError::DuplicateIndex { index: 2 }
        );
        assert_eq!(
            SparseRow::new(0.0, vec![5], vec![1.0], 5).unwrap_err(),
            SparseRowError::IndexOutOfRange { index: 5, p: 5 }
        );
        // the block form reports the same named errors
        let mut block = CsrBlock::new(3);
        assert!(matches!(
            block.push_row(0.0, &[1, 0], &[1.0, 2.0]),
            Err(SparseRowError::UnsortedIndex { .. })
        ));
        assert_eq!(block.n(), 0, "rejected rows must not land");
    }

    #[test]
    fn last_column_is_in_range() {
        // boundary: index p−1 is legal, p is not
        assert!(SparseRow::new(0.0, vec![4], vec![1.0], 5).is_ok());
        assert!(SparseRow::new(0.0, vec![5], vec![1.0], 5).is_err());
    }

    #[test]
    fn csr_rows_and_clear() {
        let mut block = CsrBlock::new(5);
        block.push_row(1.0, &[0, 4], &[1.0, 2.0]).unwrap();
        block.push_row(-1.0, &[2], &[3.0]).unwrap();
        assert_eq!(block.n(), 2);
        assert_eq!(block.nnz(), 3);
        let (idx, vals, y) = block.row(1);
        assert_eq!((idx, vals, y), (&[2usize][..], &[3.0][..], -1.0));
        block.clear();
        assert_eq!(block.n(), 0);
        assert_eq!(block.nnz(), 0);
        block.push_row(0.5, &[1], &[4.0]).unwrap();
        assert_eq!(block.row(0).2, 0.5);
    }

    #[test]
    fn error_messages_name_the_offense() {
        let e = SparseRowError::UnsortedIndex { prev: 7, next: 2 };
        assert!(e.to_string().contains("unsorted"));
        let e = SparseRowError::DuplicateIndex { index: 3 };
        assert!(e.to_string().contains("duplicate"));
        let e = SparseRowError::IndexOutOfRange { index: 9, p: 4 };
        assert!(e.to_string().contains("out of range"));
    }
}
