//! CSV shard I/O — the on-disk interchange for the CLI (`plrmr fit --csv`).
//!
//! Format: optional header, then one row per line, comma-separated, the
//! *last* column is the response y.  Writers shard a dataset into N files
//! (what a distributed filesystem would hand each mapper).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::dataset::Dataset;

/// Write `data` as a single CSV file with an `x0..x{p-1},y` header.
pub fn write_csv(data: &Dataset, path: &Path) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    let header: Vec<String> = (0..data.p)
        .map(|j| format!("x{j}"))
        .chain(std::iter::once("y".to_string()))
        .collect();
    writeln!(w, "{}", header.join(","))?;
    for i in 0..data.n() {
        let row = data.row(i);
        for v in row {
            write!(w, "{v},")?;
        }
        writeln!(w, "{}", data.y[i])?;
    }
    Ok(())
}

/// Shard `data` into `k` files `<stem>.shard-<i>.csv` under `dir`.
pub fn write_shards(data: &Dataset, dir: &Path, stem: &str, k: usize) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::with_capacity(k);
    for (i, shard) in data.shards(k).iter().enumerate() {
        let path = dir.join(format!("{stem}.shard-{i}.csv"));
        let sub = Dataset::new(shard.p, shard.x.to_vec(), shard.y.to_vec());
        write_csv(&sub, &path)?;
        paths.push(path);
    }
    Ok(paths)
}

/// Read a CSV produced by [`write_csv`] (header optional: a first line that
/// fails to parse as numbers is treated as a header).
pub fn read_csv(path: &Path) -> Result<Dataset> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut lines = BufReader::new(f).lines();
    let mut x = Vec::new();
    let mut y = Vec::new();
    let mut p: Option<usize> = None;
    let mut lineno = 0usize;
    while let Some(line) = lines.next() {
        let line = line?;
        lineno += 1;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let fields: Vec<&str> = trimmed.split(',').collect();
        if fields.len() < 2 {
            bail!("{path:?}:{lineno}: need at least one predictor and y");
        }
        let parsed: std::result::Result<Vec<f64>, _> =
            fields.iter().map(|s| s.trim().parse::<f64>()).collect();
        match parsed {
            Err(_) if lineno == 1 => continue, // header
            Err(e) => bail!("{path:?}:{lineno}: {e}"),
            Ok(vals) => {
                let row_p = vals.len() - 1;
                match p {
                    None => p = Some(row_p),
                    Some(p0) if p0 != row_p => {
                        bail!("{path:?}:{lineno}: width {row_p} != {p0}")
                    }
                    _ => {}
                }
                x.extend_from_slice(&vals[..row_p]);
                y.push(vals[row_p]);
            }
        }
    }
    let p = p.context("empty csv")?;
    Ok(Dataset::new(p, x, y))
}

/// Stream a CSV in row-blocks without materializing the file: `f(x, y)` is
/// called with row-major blocks of ≤ `block_rows` rows.  Returns (p, rows).
///
/// This is the HDFS-mapper access pattern: each engine task streams its own
/// shard in O(block) memory (see `Driver::fit_csv_shards`).
pub fn stream_csv(
    path: &Path,
    block_rows: usize,
    mut f: impl FnMut(&[f64], &[f64]),
) -> Result<(usize, usize)> {
    assert!(block_rows > 0);
    let file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let reader = BufReader::new(file);
    let mut p: Option<usize> = None;
    let mut xbuf: Vec<f64> = Vec::new();
    let mut ybuf: Vec<f64> = Vec::new();
    let mut total = 0usize;
    let mut lineno = 0usize;
    for line in reader.lines() {
        let line = line?;
        lineno += 1;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let fields: Vec<&str> = trimmed.split(',').collect();
        if fields.len() < 2 {
            bail!("{path:?}:{lineno}: need at least one predictor and y");
        }
        let parsed: std::result::Result<Vec<f64>, _> =
            fields.iter().map(|s| s.trim().parse::<f64>()).collect();
        match parsed {
            Err(_) if lineno == 1 => continue, // header
            Err(e) => bail!("{path:?}:{lineno}: {e}"),
            Ok(vals) => {
                let row_p = vals.len() - 1;
                match p {
                    None => p = Some(row_p),
                    Some(p0) if p0 != row_p => {
                        bail!("{path:?}:{lineno}: width {row_p} != {p0}")
                    }
                    _ => {}
                }
                xbuf.extend_from_slice(&vals[..row_p]);
                ybuf.push(vals[row_p]);
                total += 1;
                if ybuf.len() == block_rows {
                    f(&xbuf, &ybuf);
                    xbuf.clear();
                    ybuf.clear();
                }
            }
        }
    }
    if !ybuf.is_empty() {
        f(&xbuf, &ybuf);
    }
    let p = p.context("empty csv")?;
    Ok((p, total))
}

/// Number of predictors in a CSV (first data row's width − 1), cheaply.
pub fn peek_width(path: &Path) -> Result<usize> {
    let file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let reader = BufReader::new(file);
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let fields: Vec<&str> = trimmed.split(',').collect();
        let ok = fields.iter().all(|s| s.trim().parse::<f64>().is_ok());
        if ok && fields.len() >= 2 {
            return Ok(fields.len() - 1);
        }
        if lineno > 0 {
            bail!("{path:?}: no parsable data row found near the top");
        }
    }
    bail!("{path:?}: empty csv")
}

/// Read multiple shards and concatenate (row order = shard order).
pub fn read_shards(paths: &[PathBuf]) -> Result<Dataset> {
    let mut all: Option<Dataset> = None;
    for path in paths {
        let d = read_csv(path)?;
        match &mut all {
            None => all = Some(d),
            Some(acc) => {
                if acc.p != d.p {
                    bail!("shard width mismatch: {} vs {}", acc.p, d.p);
                }
                acc.x.extend_from_slice(&d.x);
                acc.y.extend_from_slice(&d.y);
            }
        }
    }
    all.context("no shards given")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("plrmr-csv-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn round_trip_single_file() {
        let d = generate(&SynthSpec::sparse_linear(100, 3, 0.5, 5));
        let dir = tmpdir("single");
        let path = dir.join("data.csv");
        write_csv(&d, &path).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back.p, 3);
        assert_eq!(back.n(), 100);
        for i in 0..d.x.len() {
            assert!((back.x[i] - d.x[i]).abs() < 1e-12);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn round_trip_shards() {
        let d = generate(&SynthSpec::sparse_linear(101, 2, 0.5, 6));
        let dir = tmpdir("shards");
        let paths = write_shards(&d, &dir, "w", 4).unwrap();
        assert_eq!(paths.len(), 4);
        let back = read_shards(&paths).unwrap();
        assert_eq!(back.n(), 101);
        assert_eq!(back.y, d.y);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn headerless_csv_parses() {
        let dir = tmpdir("nohdr");
        let path = dir.join("x.csv");
        std::fs::write(&path, "1.0,2.0,3.0\n4,5,6\n").unwrap();
        let d = read_csv(&path).unwrap();
        assert_eq!(d.p, 2);
        assert_eq!(d.y, vec![3.0, 6.0]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_ragged_rows() {
        let dir = tmpdir("ragged");
        let path = dir.join("x.csv");
        std::fs::write(&path, "1,2,3\n4,5\n").unwrap();
        assert!(read_csv(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_non_numeric_body() {
        let dir = tmpdir("alpha");
        let path = dir.join("x.csv");
        std::fs::write(&path, "a,b,c\n1,2,3\n4,oops,6\n").unwrap();
        assert!(read_csv(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn stream_matches_materialized_read() {
        let d = generate(&SynthSpec::sparse_linear(1000, 4, 0.5, 8));
        let dir = tmpdir("stream");
        let path = dir.join("data.csv");
        write_csv(&d, &path).unwrap();
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut blocks = 0;
        let (p, rows) = stream_csv(&path, 64, |xb, yb| {
            x.extend_from_slice(xb);
            y.extend_from_slice(yb);
            blocks += 1;
        })
        .unwrap();
        assert_eq!((p, rows), (4, 1000));
        assert_eq!(blocks, 1000usize.div_ceil(64));
        let back = read_csv(&path).unwrap();
        assert_eq!(y, back.y);
        for i in 0..x.len() {
            assert!((x[i] - back.x[i]).abs() < 1e-12);
        }
        assert_eq!(peek_width(&path).unwrap(), 4);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn stream_rejects_ragged_and_empty() {
        let dir = tmpdir("streambad");
        let bad = dir.join("bad.csv");
        std::fs::write(&bad, "1,2,3\n4,5\n").unwrap();
        assert!(stream_csv(&bad, 8, |_, _| {}).is_err());
        let empty = dir.join("empty.csv");
        std::fs::write(&empty, "").unwrap();
        assert!(stream_csv(&empty, 8, |_, _| {}).is_err());
        assert!(peek_width(&empty).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn empty_file_errors() {
        let dir = tmpdir("empty");
        let path = dir.join("x.csv");
        std::fs::write(&path, "").unwrap();
        assert!(read_csv(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
