//! CSV shard I/O — the on-disk interchange for the CLI (`plrmr fit --csv`).
//!
//! Dense format: optional header, then one row per line, comma-separated,
//! the *last* column is the response y.  Sparse format: a first line
//! `sparse p=<P>` declaring the width, then one `y index:value ...` line
//! per row carrying only the nonzero entries (strictly ascending indices —
//! violations surface as the named [`crate::data::sparse::SparseRowError`]s
//! with file:line context).  Readers auto-detect the format from line 1
//! and hand back identical dense row-blocks either way, so everything
//! downstream of the reader is format-agnostic.  Writers shard a dataset
//! into N files (what a distributed filesystem would hand each mapper).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::dataset::Dataset;
use crate::data::sparse::validate_indices;

/// Recognize a sparse-format declaration (`sparse p=<P>`) on line 1.
/// Returns None for anything else (dense header or data).
fn sparse_header_width(first_line: &str) -> Option<Result<usize>> {
    let rest = first_line.trim().strip_prefix("sparse")?;
    if !rest.starts_with(char::is_whitespace) {
        // e.g. a dense header whose first column is named `sparseness`
        return None;
    }
    Some(
        rest.trim()
            .strip_prefix("p=")
            .ok_or_else(|| anyhow!("sparse header must be `sparse p=<width>`"))
            .and_then(|w| {
                w.trim()
                    .parse::<usize>()
                    .map_err(|e| anyhow!("bad sparse width {w:?}: {e}"))
            }),
    )
}

/// Parse one `y index:value ...` line against width `p`.
fn parse_sparse_line(line: &str, p: usize) -> Result<(Vec<usize>, Vec<f64>, f64)> {
    let mut toks = line.split_whitespace();
    let y: f64 = toks
        .next()
        .context("empty sparse line")?
        .parse()
        .map_err(|e| anyhow!("bad y: {e}"))?;
    let mut idx = Vec::new();
    let mut vals = Vec::new();
    for tok in toks {
        let (i, v) = tok
            .split_once(':')
            .with_context(|| format!("expected index:value, got {tok:?}"))?;
        idx.push(i.parse::<usize>().map_err(|e| anyhow!("bad index {i:?}: {e}"))?);
        vals.push(v.parse::<f64>().map_err(|e| anyhow!("bad value {v:?}: {e}"))?);
    }
    validate_indices(&idx, p).map_err(|e| anyhow!("{e}"))?;
    Ok((idx, vals, y))
}

/// Write `data` as a single CSV file with an `x0..x{p-1},y` header.
pub fn write_csv(data: &Dataset, path: &Path) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    let header: Vec<String> = (0..data.p)
        .map(|j| format!("x{j}"))
        .chain(std::iter::once("y".to_string()))
        .collect();
    writeln!(w, "{}", header.join(","))?;
    for i in 0..data.n() {
        let row = data.row(i);
        for v in row {
            write!(w, "{v},")?;
        }
        writeln!(w, "{}", data.y[i])?;
    }
    Ok(())
}

/// Shard `data` into `k` files `<stem>.shard-<i>.csv` under `dir`.
pub fn write_shards(data: &Dataset, dir: &Path, stem: &str, k: usize) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::with_capacity(k);
    for (i, shard) in data.shards(k).iter().enumerate() {
        let path = dir.join(format!("{stem}.shard-{i}.csv"));
        let sub = Dataset::new(shard.p, shard.x.to_vec(), shard.y.to_vec());
        write_csv(&sub, &path)?;
        paths.push(path);
    }
    Ok(paths)
}

/// Write `data` in the sparse format: a `sparse p=<P>` header, then one
/// `y index:value ...` line per row carrying only the nonzero entries.
/// (A −0.0 entry is dropped like +0.0 and reads back as +0.0.)
pub fn write_sparse_csv(data: &Dataset, path: &Path) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "sparse p={}", data.p)?;
    for i in 0..data.n() {
        write!(w, "{}", data.y[i])?;
        for (j, &v) in data.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {j}:{v}")?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Shard `data` into `k` sparse-format files `<stem>.shard-<i>.csv`.
pub fn write_sparse_shards(
    data: &Dataset,
    dir: &Path,
    stem: &str,
    k: usize,
) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::with_capacity(k);
    for (i, shard) in data.shards(k).iter().enumerate() {
        let path = dir.join(format!("{stem}.shard-{i}.csv"));
        let sub = Dataset::new(shard.p, shard.x.to_vec(), shard.y.to_vec());
        write_sparse_csv(&sub, &path)?;
        paths.push(path);
    }
    Ok(paths)
}

/// Read a CSV produced by [`write_csv`] (header optional: a first line that
/// fails to parse as numbers is treated as a header).
pub fn read_csv(path: &Path) -> Result<Dataset> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut lines = BufReader::new(f).lines();
    let mut x = Vec::new();
    let mut y = Vec::new();
    let mut p: Option<usize> = None;
    let mut lineno = 0usize;
    let mut sparse_p: Option<usize> = None;
    while let Some(line) = lines.next() {
        let line = line?;
        lineno += 1;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if lineno == 1 {
            if let Some(width) = sparse_header_width(trimmed) {
                let width = width.with_context(|| format!("{path:?}:1"))?;
                sparse_p = Some(width);
                p = Some(width);
                continue;
            }
        }
        if let Some(width) = sparse_p {
            let (idx, vals, yv) =
                parse_sparse_line(trimmed, width).with_context(|| format!("{path:?}:{lineno}"))?;
            let base = x.len();
            x.resize(base + width, 0.0);
            for (&j, &v) in idx.iter().zip(&vals) {
                x[base + j] = v;
            }
            y.push(yv);
            continue;
        }
        let fields: Vec<&str> = trimmed.split(',').collect();
        if fields.len() < 2 {
            bail!("{path:?}:{lineno}: need at least one predictor and y");
        }
        let parsed: std::result::Result<Vec<f64>, _> =
            fields.iter().map(|s| s.trim().parse::<f64>()).collect();
        match parsed {
            Err(_) if lineno == 1 => continue, // header
            Err(e) => bail!("{path:?}:{lineno}: {e}"),
            Ok(vals) => {
                let row_p = vals.len() - 1;
                match p {
                    None => p = Some(row_p),
                    Some(p0) if p0 != row_p => {
                        bail!("{path:?}:{lineno}: width {row_p} != {p0}")
                    }
                    _ => {}
                }
                x.extend_from_slice(&vals[..row_p]);
                y.push(vals[row_p]);
            }
        }
    }
    let p = p.context("empty csv")?;
    Ok(Dataset::new(p, x, y))
}

/// Stream a CSV in row-blocks without materializing the file: `f(x, y)` is
/// called with row-major blocks of ≤ `block_rows` rows.  Returns (p, rows).
///
/// This is the HDFS-mapper access pattern: each engine task streams its own
/// shard in O(block) memory (see `Driver::fit_csv_shards`).
pub fn stream_csv(
    path: &Path,
    block_rows: usize,
    mut f: impl FnMut(&[f64], &[f64]),
) -> Result<(usize, usize)> {
    assert!(block_rows > 0);
    let file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let reader = BufReader::new(file);
    let mut p: Option<usize> = None;
    let mut xbuf: Vec<f64> = Vec::new();
    let mut ybuf: Vec<f64> = Vec::new();
    let mut total = 0usize;
    let mut lineno = 0usize;
    let mut sparse_p: Option<usize> = None;
    for line in reader.lines() {
        let line = line?;
        lineno += 1;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if lineno == 1 {
            if let Some(width) = sparse_header_width(trimmed) {
                let width = width.with_context(|| format!("{path:?}:1"))?;
                sparse_p = Some(width);
                p = Some(width);
                continue;
            }
        }
        if let Some(width) = sparse_p {
            let (idx, vals, yv) =
                parse_sparse_line(trimmed, width).with_context(|| format!("{path:?}:{lineno}"))?;
            // densify into the block buffer: downstream consumers see the
            // same row-major blocks the dense reader produces
            let base = xbuf.len();
            xbuf.resize(base + width, 0.0);
            for (&j, &v) in idx.iter().zip(&vals) {
                xbuf[base + j] = v;
            }
            ybuf.push(yv);
            total += 1;
            if ybuf.len() == block_rows {
                f(&xbuf, &ybuf);
                xbuf.clear();
                ybuf.clear();
            }
            continue;
        }
        let fields: Vec<&str> = trimmed.split(',').collect();
        if fields.len() < 2 {
            bail!("{path:?}:{lineno}: need at least one predictor and y");
        }
        let parsed: std::result::Result<Vec<f64>, _> =
            fields.iter().map(|s| s.trim().parse::<f64>()).collect();
        match parsed {
            Err(_) if lineno == 1 => continue, // header
            Err(e) => bail!("{path:?}:{lineno}: {e}"),
            Ok(vals) => {
                let row_p = vals.len() - 1;
                match p {
                    None => p = Some(row_p),
                    Some(p0) if p0 != row_p => {
                        bail!("{path:?}:{lineno}: width {row_p} != {p0}")
                    }
                    _ => {}
                }
                xbuf.extend_from_slice(&vals[..row_p]);
                ybuf.push(vals[row_p]);
                total += 1;
                if ybuf.len() == block_rows {
                    f(&xbuf, &ybuf);
                    xbuf.clear();
                    ybuf.clear();
                }
            }
        }
    }
    if !ybuf.is_empty() {
        f(&xbuf, &ybuf);
    }
    let p = p.context("empty csv")?;
    Ok((p, total))
}

/// Number of predictors in a CSV (first data row's width − 1), cheaply.
pub fn peek_width(path: &Path) -> Result<usize> {
    let file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let reader = BufReader::new(file);
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if lineno == 0 {
            if let Some(width) = sparse_header_width(trimmed) {
                return width.with_context(|| format!("{path:?}:1"));
            }
        }
        let fields: Vec<&str> = trimmed.split(',').collect();
        let ok = fields.iter().all(|s| s.trim().parse::<f64>().is_ok());
        if ok && fields.len() >= 2 {
            return Ok(fields.len() - 1);
        }
        if lineno > 0 {
            bail!("{path:?}: no parsable data row found near the top");
        }
    }
    bail!("{path:?}: empty csv")
}

/// Read multiple shards and concatenate (row order = shard order).
pub fn read_shards(paths: &[PathBuf]) -> Result<Dataset> {
    let mut all: Option<Dataset> = None;
    for path in paths {
        let d = read_csv(path)?;
        match &mut all {
            None => all = Some(d),
            Some(acc) => {
                if acc.p != d.p {
                    bail!("shard width mismatch: {} vs {}", acc.p, d.p);
                }
                acc.x.extend_from_slice(&d.x);
                acc.y.extend_from_slice(&d.y);
            }
        }
    }
    all.context("no shards given")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("plrmr-csv-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn round_trip_single_file() {
        let d = generate(&SynthSpec::sparse_linear(100, 3, 0.5, 5));
        let dir = tmpdir("single");
        let path = dir.join("data.csv");
        write_csv(&d, &path).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back.p, 3);
        assert_eq!(back.n(), 100);
        for i in 0..d.x.len() {
            assert!((back.x[i] - d.x[i]).abs() < 1e-12);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn round_trip_shards() {
        let d = generate(&SynthSpec::sparse_linear(101, 2, 0.5, 6));
        let dir = tmpdir("shards");
        let paths = write_shards(&d, &dir, "w", 4).unwrap();
        assert_eq!(paths.len(), 4);
        let back = read_shards(&paths).unwrap();
        assert_eq!(back.n(), 101);
        assert_eq!(back.y, d.y);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn headerless_csv_parses() {
        let dir = tmpdir("nohdr");
        let path = dir.join("x.csv");
        std::fs::write(&path, "1.0,2.0,3.0\n4,5,6\n").unwrap();
        let d = read_csv(&path).unwrap();
        assert_eq!(d.p, 2);
        assert_eq!(d.y, vec![3.0, 6.0]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_ragged_rows() {
        let dir = tmpdir("ragged");
        let path = dir.join("x.csv");
        std::fs::write(&path, "1,2,3\n4,5\n").unwrap();
        assert!(read_csv(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_non_numeric_body() {
        let dir = tmpdir("alpha");
        let path = dir.join("x.csv");
        std::fs::write(&path, "a,b,c\n1,2,3\n4,oops,6\n").unwrap();
        assert!(read_csv(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn stream_matches_materialized_read() {
        let d = generate(&SynthSpec::sparse_linear(1000, 4, 0.5, 8));
        let dir = tmpdir("stream");
        let path = dir.join("data.csv");
        write_csv(&d, &path).unwrap();
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut blocks = 0;
        let (p, rows) = stream_csv(&path, 64, |xb, yb| {
            x.extend_from_slice(xb);
            y.extend_from_slice(yb);
            blocks += 1;
        })
        .unwrap();
        assert_eq!((p, rows), (4, 1000));
        assert_eq!(blocks, 1000usize.div_ceil(64));
        let back = read_csv(&path).unwrap();
        assert_eq!(y, back.y);
        for i in 0..x.len() {
            assert!((x[i] - back.x[i]).abs() < 1e-12);
        }
        assert_eq!(peek_width(&path).unwrap(), 4);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn stream_rejects_ragged_and_empty() {
        let dir = tmpdir("streambad");
        let bad = dir.join("bad.csv");
        std::fs::write(&bad, "1,2,3\n4,5\n").unwrap();
        assert!(stream_csv(&bad, 8, |_, _| {}).is_err());
        let empty = dir.join("empty.csv");
        std::fs::write(&empty, "").unwrap();
        assert!(stream_csv(&empty, 8, |_, _| {}).is_err());
        assert!(peek_width(&empty).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn sparse_round_trip_bitwise() {
        // sparse write → auto-detected read reproduces the dense values
        // exactly (f64 Display round-trips shortest-exact)
        let mut d = generate(&SynthSpec::sparse_linear(120, 6, 0.5, 14));
        // zero most entries so the file is genuinely sparse, keep one
        // all-zero row as the degenerate case
        for (i, v) in d.x.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        for v in &mut d.x[..6] {
            *v = 0.0;
        }
        let dir = tmpdir("sparse-rt");
        let path = dir.join("data.csv");
        write_sparse_csv(&d, &path).unwrap();
        assert_eq!(peek_width(&path).unwrap(), 6);
        let back = read_csv(&path).unwrap();
        assert_eq!(back.p, 6);
        assert_eq!(back.n(), 120);
        for i in 0..d.x.len() {
            assert_eq!(back.x[i].to_bits(), d.x[i].to_bits(), "x[{i}]");
        }
        for i in 0..d.y.len() {
            assert_eq!(back.y[i].to_bits(), d.y[i].to_bits(), "y[{i}]");
        }
        // streaming read produces the same blocks
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let (p, rows) = stream_csv(&path, 32, |xb, yb| {
            xs.extend_from_slice(xb);
            ys.extend_from_slice(yb);
        })
        .unwrap();
        assert_eq!((p, rows), (6, 120));
        assert_eq!(xs, d.x);
        assert_eq!(ys, d.y);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn sparse_shards_concatenate() {
        let mut d = generate(&SynthSpec::sparse_linear(57, 4, 0.5, 3));
        for (i, v) in d.x.iter_mut().enumerate() {
            if i % 2 == 0 {
                *v = 0.0;
            }
        }
        let dir = tmpdir("sparse-shards");
        let paths = write_sparse_shards(&d, &dir, "w", 3).unwrap();
        assert_eq!(paths.len(), 3);
        let back = read_shards(&paths).unwrap();
        assert_eq!(back.n(), 57);
        assert_eq!(back.y, d.y);
        assert_eq!(back.x, d.x);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn sparse_format_errors_are_named_with_location() {
        let dir = tmpdir("sparse-bad");
        let cases = [
            ("dup", "sparse p=4\n1.0 2:1.0 2:2.0\n", "duplicate"),
            ("unsorted", "sparse p=4\n1.0 3:1.0 1:2.0\n", "unsorted"),
            ("range", "sparse p=4\n1.0 4:1.0\n", "out of range"),
            ("pair", "sparse p=4\n1.0 3=1.0\n", "index:value"),
            ("header", "sparse q=4\n1.0 1:1.0\n", "sparse p=<width>"),
        ];
        for (tag, body, needle) in cases {
            let path = dir.join(format!("{tag}.csv"));
            std::fs::write(&path, body).unwrap();
            let err = format!("{:?}", read_csv(&path).unwrap_err());
            assert!(err.contains(needle), "{tag}: {err}");
            let err = format!("{:?}", stream_csv(&path, 8, |_, _| {}).unwrap_err());
            assert!(err.contains(needle), "stream {tag}: {err}");
        }
        // data-line errors carry file:line context
        let path = dir.join("dup.csv");
        let err = format!("{:?}", read_csv(&path).unwrap_err());
        assert!(err.contains(":2"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn sparse_all_zero_rows_parse() {
        let dir = tmpdir("sparse-zero");
        let path = dir.join("z.csv");
        std::fs::write(&path, "sparse p=3\n1.5\n-2.5 1:4.0\n").unwrap();
        let d = read_csv(&path).unwrap();
        assert_eq!(d.p, 3);
        assert_eq!(d.y, vec![1.5, -2.5]);
        assert_eq!(d.x, vec![0.0, 0.0, 0.0, 0.0, 4.0, 0.0]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn empty_file_errors() {
        let dir = tmpdir("empty");
        let path = dir.join("x.csv");
        std::fs::write(&path, "").unwrap();
        assert!(read_csv(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
