//! Data substrate: in-memory datasets, streaming blocks, synthetic
//! generators, and CSV shard I/O.
//!
//! The paper's data lives on HDFS at billions-of-rows scale; the one-pass
//! property is about the *access pattern* (each row touched exactly once),
//! not the storage medium.  [`dataset::Dataset`] holds materialized data
//! for exactness checks; [`synth::SynthStream`] produces unbounded
//! row-blocks without materializing anything, which is what the scaling
//! experiments (F1) iterate over; [`csv`] round-trips shard files so the
//! CLI can run against files on disk.

pub mod csv;
pub mod dataset;
pub mod sparse;
pub mod synth;

pub use dataset::{DataBlock, Dataset};
pub use sparse::{CsrBlock, SparseRow, SparseRowError};
