//! Wall-clock timing helpers used by the engine metrics and bench harness.

use std::time::{Duration, Instant};

/// A simple start/lap timer.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
    last: Instant,
}

impl Timer {
    pub fn start() -> Self {
        let now = Instant::now();
        Timer { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous `lap` (or construction), and reset the lap.
    pub fn lap_s(&mut self) -> f64 {
        let now = Instant::now();
        let d = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        d
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Human-friendly duration formatting for logs/tables.
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        // NaN/±inf would otherwise fall through the < chain into the
        // minutes arm and print "NaNm" — surface the value undisguised
        return format!("{s}s");
    }
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}m", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let mut t = Timer::start();
        let a = t.lap_s();
        let b = t.elapsed_s();
        assert!(a >= 0.0 && b >= a);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, s) = time_it(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-10).ends_with("ns"));
        assert!(fmt_secs(5e-5).ends_with("µs"));
        assert!(fmt_secs(5e-2).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
        assert!(fmt_secs(600.0).ends_with('m'));
    }

    #[test]
    fn fmt_boundaries_and_guards() {
        // exact unit boundaries pick the larger unit (the `<` chain)
        assert_eq!(fmt_secs(0.0), "0.0ns");
        assert_eq!(fmt_secs(1e-6), "1.0µs");
        assert_eq!(fmt_secs(1e-3), "1.00ms");
        assert_eq!(fmt_secs(1.0), "1.00s");
        assert_eq!(fmt_secs(119.999), "120.00s", "just under the minutes cut stays seconds");
        assert_eq!(fmt_secs(120.0), "2.0m", "exactly 120s flips to minutes");
        // non-finite inputs never masquerade as minutes
        assert_eq!(fmt_secs(f64::NAN), "NaNs");
        assert_eq!(fmt_secs(f64::INFINITY), "infs");
    }
}
