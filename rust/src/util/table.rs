//! Fixed-width table rendering for the experiments harness — every T*/F*
//! experiment prints its rows through this so EXPERIMENTS.md and terminal
//! output share one format (GitHub-flavoured markdown pipe tables).

/// A column-aligned markdown table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "table row width mismatch"
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as a GitHub-flavoured markdown pipe table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = width[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i] - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = line(&self.header);
        out.push('\n');
        let sep: Vec<String> = width.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&line(&sep));
        for row in &self.rows {
            out.push('\n');
            out.push_str(&line(row));
        }
        out
    }
}

/// Format a float with engineering-friendly significant digits.
pub fn sig(x: f64, digits: usize) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    if !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    if (-3..6).contains(&mag) {
        let dec = (digits as i32 - 1 - mag).max(0) as usize;
        format!("{x:.dec$}")
    } else {
        format!("{x:.prec$e}", prec = digits.saturating_sub(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["alpha", "1"]).row(vec!["b", "12345"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("| name "));
        assert!(lines[1].starts_with("| ----"));
        // all lines equal width
        assert!(lines.iter().all(|l| l.chars().count() == lines[0].chars().count()));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        Table::new(vec!["a"]).row(vec!["1", "2"]);
    }

    #[test]
    fn sig_formatting() {
        assert_eq!(sig(0.0, 3), "0");
        assert_eq!(sig(1234.6, 4), "1235".to_string());
        assert_eq!(sig(0.012345, 3), "0.0123");
        assert!(sig(1.5e9, 3).contains('e'));
        assert!(sig(f64::NAN, 3).contains("NaN"));
    }
}
