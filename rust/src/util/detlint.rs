//! `detlint` — the in-repo determinism linter.
//!
//! The bit-identity matrix (packed = tiled = spilled = screened = sparse =
//! proc-workers, bit for bit) is enforced *dynamically* by tests; this
//! module is the static counterpart.  It walks `rust/src` and flags the
//! hazard patterns that historically break run-to-run reproducibility:
//!
//! * **`raw-lock`** — `.lock().unwrap()` / `.lock().expect(…)` anywhere
//!   outside [`crate::sync`].  Raw lock+unwrap turns one worker's panic
//!   into a `PoisonError` cascade in innocent threads; the shim's
//!   `lock_named`/`wait_named` carry the poison policy instead.
//! * **`hash-collection`** — `HashMap`/`HashSet`.  Their iteration order
//!   is randomized per process; any walk that feeds emitted, merged,
//!   scheduled or logged output reorders run-to-run.  Use
//!   `BTreeMap`/`BTreeSet`, or name the exception in the allowlist.
//! * **`time-in-keyed`** — `Instant::now`/`SystemTime::now` inside keyed
//!   paths (map/merge/store/solver code).  Wall-clock metrics around a
//!   phase are fine (and allowlisted); time *inside* keyed logic is how
//!   timing sneaks into payloads.
//! * **`rand-nondet`** — `thread_rng`/`from_entropy`/`RandomState`/
//!   `rand::random` inside keyed paths.  All randomness must come from
//!   the crate's seeded [`crate::rng`].
//! * **`float-accum`** — `.sum::<f64>()`-style iterator accumulation in
//!   keyed paths outside the sanctioned kernel modules (`stats/*`, where
//!   summation order is pinned and Kahan-compensated).  Unpinned float
//!   accumulation is exactly the non-associativity the fixed merge tree
//!   exists to contain.
//! * **`simd-intrinsics`** — `std::arch`/`core::arch`/`target_feature`
//!   anywhere outside [`crate::stats::simd`].  That module is the ONE
//!   sanctioned vector-kernel boundary: its kernels are mul-then-add with
//!   a fixed per-element order (no FMA, no horizontal reductions) and are
//!   property-tested bit-identical to the scalar oracles.  Intrinsics
//!   sprinkled anywhere else would not carry those proofs.
//! * **`wallclock-outside-trace`** — `Instant::now`/`SystemTime::now`
//!   anywhere outside [`crate::trace`] (where wall-clock is a sanctioned
//!   *event payload*, never keyed data) and the allowlisted timing
//!   surfaces (`util/timer.rs`, supervision deadlines).  Everything else
//!   takes time through `util::timer::Timer` or `trace::now_us`, so a
//!   grep for `Instant::now` enumerates every clock in the tree.
//!
//! Scanning is line-based and deliberately dumb: comments are stripped
//! (everything from the first `//`), and a file stops being scanned at
//! its trailing `#[cfg(test…)] mod …` block — tests may use whatever they
//! like.  Every surviving exception must be named in `detlint.allow`
//! (`rule path-suffix  # justification`), and unused allow entries are
//! themselves errors, so the list cannot rot.
//!
//! Run it as `cargo detlint` (alias for `cargo run --bin detlint`); CI
//! runs it beside clippy.  The library half lives here so a unit test can
//! assert the current tree is clean (`detlint_passes_on_the_current_tree`).

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Path prefixes (relative to `src/`, `/`-separated) considered *keyed*:
/// code on these paths computes, merges, stores or schedules the
/// deterministic statistics and therefore gets the stricter rule set.
pub const KEYED_PREFIXES: &[&str] =
    &["mapreduce/", "store/", "stats/", "cv/", "solver/", "coordinator/", "data/"];

/// Where a rule applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Scope {
    /// every scanned file
    All,
    /// files under [`KEYED_PREFIXES`]
    Keyed,
    /// keyed files minus the sanctioned float-kernel modules (`stats/`)
    KeyedNonKernel,
}

struct Rule {
    name: &'static str,
    needles: &'static [&'static str],
    scope: Scope,
    why: &'static str,
}

const RULES: &[Rule] = &[
    Rule {
        name: "raw-lock",
        needles: &[".lock().unwrap()", ".lock().expect("],
        scope: Scope::All,
        why: "bypasses the poison policy; use crate::sync::{lock_named, wait_named}",
    },
    Rule {
        name: "hash-collection",
        needles: &["HashMap", "HashSet"],
        scope: Scope::All,
        why: "iteration order is randomized per process; use BTreeMap/BTreeSet",
    },
    Rule {
        name: "time-in-keyed",
        needles: &["Instant::now", "SystemTime::now"],
        scope: Scope::Keyed,
        why: "wall-clock reads on a keyed path can leak timing into keyed logic",
    },
    Rule {
        name: "rand-nondet",
        needles: &["thread_rng", "from_entropy", "RandomState", "rand::random"],
        scope: Scope::Keyed,
        why: "unseeded randomness on a keyed path; use the seeded crate::rng",
    },
    Rule {
        name: "float-accum",
        needles: &[".sum::<f64>(", ".sum::<f32>(", ".product::<f64>(", ".product::<f32>("],
        scope: Scope::KeyedNonKernel,
        why: "unpinned float accumulation outside the sanctioned stats kernels",
    },
    Rule {
        name: "simd-intrinsics",
        needles: &["std::arch", "core::arch", "target_feature"],
        scope: Scope::All,
        why: "vector intrinsics outside the sanctioned stats/simd.rs microkernel boundary",
    },
    Rule {
        name: "wallclock-outside-trace",
        needles: &["Instant::now", "SystemTime::now"],
        scope: Scope::All,
        why: "raw wall-clock outside trace/; use util::timer::Timer or trace::now_us",
    },
];

/// One hazard the linter found (after allowlist filtering, in [`Report`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// rule name, e.g. `raw-lock`
    pub rule: &'static str,
    /// path relative to the scanned root, `/`-separated
    pub path: String,
    /// 1-based line number
    pub line: usize,
    /// the offending line, comment-stripped and trimmed
    pub excerpt: String,
    /// one-line rationale for the rule
    pub why: &'static str,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}\n    {}",
            self.path, self.line, self.rule, self.why, self.excerpt
        )
    }
}

/// One parsed `detlint.allow` entry: `rule path-suffix  # justification`.
#[derive(Debug, Clone)]
struct Allow {
    rule: String,
    suffix: String,
    line: usize,
    used: bool,
}

/// The outcome of one linter run.
#[derive(Debug, Default)]
pub struct Report {
    /// hazards NOT covered by the allowlist — each one fails the run
    pub findings: Vec<Finding>,
    /// hazards suppressed by a named allowlist entry
    pub allowed: usize,
    /// allowlist entries that matched nothing — each one fails the run,
    /// so stale exceptions cannot linger unreviewed
    pub unused_allows: Vec<String>,
    /// files scanned (sanity signal that the walk found the tree)
    pub files_scanned: usize,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty() && self.unused_allows.is_empty()
    }
}

/// Run the linter over every `.rs` file under `src_root`, filtering
/// through the allowlist at `allow_path` (a missing allowlist is an empty
/// one).  Deterministic by construction: files are visited in sorted
/// path order, lines top to bottom, rules in declaration order.
pub fn run(src_root: &Path, allow_path: &Path) -> Result<Report> {
    let mut allows = parse_allowlist(allow_path)?;
    let mut files = Vec::new();
    collect_rs_files(src_root, src_root, &mut files)?;
    files.sort();

    let mut report = Report::default();
    for rel in &files {
        if !scan_whole_file(rel) {
            continue;
        }
        report.files_scanned += 1;
        let text = fs::read_to_string(src_root.join(rel))
            .with_context(|| format!("read {rel} under {src_root:?}"))?;
        for finding in scan_file(rel, &text) {
            match allows
                .iter_mut()
                .find(|a| a.rule == finding.rule && finding.path.ends_with(&a.suffix))
            {
                Some(a) => {
                    a.used = true;
                    report.allowed += 1;
                }
                None => report.findings.push(finding),
            }
        }
    }
    for a in &allows {
        if !a.used {
            report
                .unused_allows
                .push(format!("{} {} (detlint.allow line {})", a.rule, a.suffix, a.line));
        }
    }
    Ok(report)
}

/// Files the linter never scans: its own sources (whose rule tables
/// contain every needle verbatim) and the thin CLI wrapper around them.
fn scan_whole_file(rel: &str) -> bool {
    rel != "util/detlint.rs" && !rel.starts_with("bin/")
}

/// Rule-level exemptions: `sync.rs` IS the sanctioned lock surface,
/// `stats/simd.rs` IS the sanctioned vector-kernel boundary, and `trace/`
/// IS the sanctioned wall-clock payload surface.
fn rule_applies(rule: &Rule, rel: &str) -> bool {
    if rule.name == "raw-lock" && rel == "sync.rs" {
        return false;
    }
    if rule.name == "simd-intrinsics" && rel == "stats/simd.rs" {
        return false;
    }
    if rule.name == "wallclock-outside-trace" && rel.starts_with("trace/") {
        return false;
    }
    match rule.scope {
        Scope::All => true,
        Scope::Keyed => KEYED_PREFIXES.iter().any(|p| rel.starts_with(p)),
        Scope::KeyedNonKernel => {
            KEYED_PREFIXES.iter().any(|p| rel.starts_with(p)) && !rel.starts_with("stats/")
        }
    }
}

fn scan_file(rel: &str, text: &str) -> Vec<Finding> {
    let lines: Vec<&str> = text.lines().collect();
    let mut findings = Vec::new();
    for (idx, raw) in lines.iter().enumerate() {
        if starts_test_module(&lines, idx) {
            break;
        }
        // strip line comments (also covers `///` and `//!` docs); a `//`
        // inside a string literal truncates the scan of that line, which
        // can only hide, never invent, a finding
        let code = raw.split("//").next().unwrap_or("").trim();
        if code.is_empty() {
            continue;
        }
        for rule in RULES {
            if !rule_applies(rule, rel) {
                continue;
            }
            if rule.needles.iter().any(|n| code.contains(n)) {
                findings.push(Finding {
                    rule: rule.name,
                    path: rel.to_string(),
                    line: idx + 1,
                    excerpt: code.to_string(),
                    why: rule.why,
                });
            }
        }
    }
    findings
}

/// True when line `idx` opens the file's test block: a `#[cfg(…test…)]`
/// attribute whose next substantive line (skipping further attributes and
/// comments) declares a `mod`.  Scanning stops there — everything below
/// is test code, exempt by design.  `#[cfg(test)]` on individual items
/// (fields, helpers) does NOT stop the scan.
fn starts_test_module(lines: &[&str], idx: usize) -> bool {
    let t = lines[idx].trim();
    if !(t.starts_with("#[cfg(") && t.contains("test")) {
        return false;
    }
    for next in lines.iter().skip(idx + 1) {
        let n = next.trim();
        if n.is_empty() || n.starts_with("#[") || n.starts_with("//") {
            continue;
        }
        return ["mod ", "pub mod ", "pub(crate) mod "].iter().any(|p| n.starts_with(p));
    }
    false
}

fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let entries =
        fs::read_dir(dir).with_context(|| format!("walk source directory {dir:?}"))?;
    for entry in entries {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

fn parse_allowlist(path: &Path) -> Result<Vec<Allow>> {
    let Ok(text) = fs::read_to_string(path) else {
        return Ok(Vec::new());
    };
    let known: Vec<&str> = RULES.iter().map(|r| r.name).collect();
    let mut allows = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(rule), Some(suffix), None) = (parts.next(), parts.next(), parts.next()) else {
            bail!(
                "detlint.allow line {}: expected `rule path-suffix  # justification`, got {raw:?}",
                idx + 1
            );
        };
        if !known.contains(&rule) {
            bail!(
                "detlint.allow line {}: unknown rule {rule:?} (known: {})",
                idx + 1,
                known.join(", ")
            );
        }
        let justification = raw.split('#').nth(1).map(str::trim).unwrap_or("");
        if justification.is_empty() {
            bail!(
                "detlint.allow line {}: every exception needs a `# justification`",
                idx + 1
            );
        }
        allows.push(Allow {
            rule: rule.to_string(),
            suffix: suffix.to_string(),
            line: idx + 1,
            used: false,
        });
    }
    Ok(allows)
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    /// Unique scratch dir per fixture (no tempfile dep in this crate).
    fn fixture(files: &[(&str, &str)], allow: &str) -> (PathBuf, PathBuf) {
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let root = std::env::temp_dir()
            .join(format!("plrmr-detlint-{}-{seq}", std::process::id()));
        let src = root.join("src");
        for (rel, text) in files {
            let path = src.join(rel);
            fs::create_dir_all(path.parent().unwrap()).unwrap();
            fs::write(path, text).unwrap();
        }
        let allow_path = root.join("detlint.allow");
        fs::write(&allow_path, allow).unwrap();
        (src, allow_path)
    }

    fn rules_hit(report: &Report) -> Vec<&'static str> {
        report.findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn each_rule_fires_in_its_scope_and_not_outside() {
        let (src, allow) = fixture(
            &[
                ("mapreduce/engine.rs", "fn f() { let _g = m.lock().unwrap(); }\n"),
                ("store/spill.rs", "use std::collections::HashMap;\n"),
                // the Instant line fires BOTH time-in-keyed (keyed path)
                // and wallclock-outside-trace (everywhere)
                ("solver/cd.rs", "let t = Instant::now();\nlet s: f64 = xs.iter().sum::<f64>();\n"),
                ("cv/folds.rs", "let r = thread_rng();\n"),
                ("data/ingest.rs", "use std::arch::x86_64::_mm256_add_pd;\n"),
                // util/ is outside the keyed scope (no time-in-keyed) but
                // still inside wallclock-outside-trace's Scope::All
                ("util/timer.rs", "let t = Instant::now();\n"),
                // out of scope: accumulation in stats/, locks in sync.rs,
                // intrinsics in stats/simd.rs, wall-clock in trace/
                ("stats/kahan.rs", "let s: f64 = xs.iter().sum::<f64>();\n"),
                ("sync.rs", "let g = m.lock().unwrap();\n"),
                ("stats/simd.rs", "use core::arch::x86_64::_mm256_mul_pd;\n"),
                ("trace/mod.rs", "let t = Instant::now();\n"),
            ],
            "",
        );
        let report = run(&src, &allow).unwrap();
        let mut hit = rules_hit(&report);
        hit.sort();
        assert_eq!(
            hit,
            vec![
                "float-accum",
                "hash-collection",
                "rand-nondet",
                "raw-lock",
                "simd-intrinsics",
                "time-in-keyed",
                "wallclock-outside-trace",
                "wallclock-outside-trace"
            ]
        );
        assert_eq!(report.findings.len(), 8, "{:#?}", report.findings);
        assert_eq!(report.files_scanned, 10);
        let _ = fs::remove_dir_all(src.parent().unwrap());
    }

    #[test]
    fn comments_and_trailing_test_modules_are_exempt() {
        let text = "\
// HashMap in a comment is fine
/// so is .lock().unwrap() in docs
fn real() {}
#[cfg(test)]
mod tests {
    use std::collections::HashMap; // tests may hash
    fn t() { let _g = m.lock().unwrap(); }
}
";
        let (src, allow) = fixture(&[("mapreduce/engine.rs", text)], "");
        let report = run(&src, &allow).unwrap();
        assert!(report.is_clean(), "{:#?}", report.findings);
        let _ = fs::remove_dir_all(src.parent().unwrap());
    }

    #[test]
    fn cfg_test_on_an_item_does_not_stop_the_scan() {
        let text = "\
#[cfg(test)]
type ThreadTask = u8;
fn real() { let _g = m.lock().unwrap(); }
";
        let (src, allow) = fixture(&[("mapreduce/supervisor.rs", text)], "");
        let report = run(&src, &allow).unwrap();
        assert_eq!(rules_hit(&report), vec!["raw-lock"], "{:#?}", report.findings);
        let _ = fs::remove_dir_all(src.parent().unwrap());
    }

    #[test]
    fn allowlist_suppresses_by_suffix_and_flags_unused_entries() {
        let (src, allow) = fixture(
            &[("runtime/client.rs", "use std::collections::HashMap;\n")],
            "hash-collection runtime/client.rs  # reviewed: cache keyed by path, never iterated\n\
             raw-lock store/spill.rs            # stale entry, matches nothing\n",
        );
        let report = run(&src, &allow).unwrap();
        assert!(report.findings.is_empty(), "{:#?}", report.findings);
        assert_eq!(report.allowed, 1);
        assert_eq!(report.unused_allows.len(), 1, "{:?}", report.unused_allows);
        assert!(report.unused_allows[0].contains("store/spill.rs"));
        assert!(!report.is_clean(), "unused entries must fail the run");
        let _ = fs::remove_dir_all(src.parent().unwrap());
    }

    #[test]
    fn allowlist_rejects_malformed_and_unjustified_entries() {
        let (src, allow) = fixture(&[("a.rs", "fn a() {}\n")], "raw-lock\n");
        assert!(run(&src, &allow).is_err(), "one-token entry must be rejected");
        fs::write(&allow, "raw-lock store/spill.rs\n").unwrap();
        let err = run(&src, &allow).unwrap_err().to_string();
        assert!(err.contains("justification"), "{err}");
        fs::write(&allow, "no-such-rule store/spill.rs # why\n").unwrap();
        let err = run(&src, &allow).unwrap_err().to_string();
        assert!(err.contains("unknown rule"), "{err}");
        let _ = fs::remove_dir_all(src.parent().unwrap());
    }

    /// The self-check the CI step relies on: the crate's own tree, with
    /// the checked-in allowlist, is clean.  If this fails, either remove
    /// the hazard or add a *justified* entry to `detlint.allow`.
    #[test]
    fn detlint_passes_on_the_current_tree() {
        let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = run(&manifest.join("src"), &manifest.join("../detlint.allow")).unwrap();
        assert!(report.files_scanned > 20, "walk found only {} files", report.files_scanned);
        let mut msg = String::new();
        for f in &report.findings {
            msg.push_str(&format!("{f}\n"));
        }
        for u in &report.unused_allows {
            msg.push_str(&format!("unused allow entry: {u}\n"));
        }
        assert!(report.is_clean(), "detlint found hazards:\n{msg}");
    }
}
