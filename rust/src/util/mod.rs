//! Small std-only utilities shared across the crate.
//!
//! The image builds offline against a vendored crate set that carries only
//! `xla` + `anyhow`, so the usual ecosystem helpers are implemented here:
//! a minimal JSON parser ([`json`]) for the artifact manifest, a wall-clock
//! timer ([`timer`]), a fixed-width table printer ([`table`]) used by the
//! experiments harness, and a tiny seeded property-testing loop ([`prop`])
//! standing in for `proptest`.

pub mod detlint;
pub mod json;
pub mod prop;
pub mod table;
pub mod timer;

/// Mean of a slice (0.0 for empty — callers guard length).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation of a slice (÷n).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Sample standard deviation of a slice (÷(n−1), Bessel-corrected) — the
/// convention behind glmnet's cross-validation standard error: the k fold
/// MSEs are a *sample* of the fold distribution, so their SD must divide
/// by k−1, not k, or the 1-SE rule's threshold is biased low.
pub fn sample_std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Max absolute difference between two equal-length slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "max_abs_diff: length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// L2 norm of a slice.
pub fn l2_norm(xs: &[f64]) -> f64 {
    xs.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Relative L2 error ‖a−b‖ / max(‖b‖, eps).
pub fn rel_l2_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt();
    num / l2_norm(b).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sample_std_dev_divides_by_n_minus_one() {
        // same data as above: Σ(x−x̄)² = 32 over n = 8 → sample SD √(32/7)
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let want = (32.0_f64 / 7.0).sqrt();
        assert!((sample_std_dev(&xs) - want).abs() < 1e-12);
        // Bessel relation: sample = population · √(n/(n−1))
        let rel = std_dev(&xs) * (8.0_f64 / 7.0).sqrt();
        assert!((sample_std_dev(&xs) - rel).abs() < 1e-12);
        // degenerate lengths stay 0 (never NaN)
        assert_eq!(sample_std_dev(&[5.0]), 0.0);
        assert_eq!(sample_std_dev(&[]), 0.0);
    }

    #[test]
    fn diffs_and_norms() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert!(rel_l2_err(&[1.0, 0.0], &[1.0, 0.0]) == 0.0);
        assert!((rel_l2_err(&[2.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn max_abs_diff_length_mismatch_panics() {
        max_abs_diff(&[1.0], &[1.0, 2.0]);
    }
}
