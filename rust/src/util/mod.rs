//! Small std-only utilities shared across the crate.
//!
//! The image builds offline against a vendored crate set that carries only
//! `xla` + `anyhow`, so the usual ecosystem helpers are implemented here:
//! a minimal JSON parser ([`json`]) for the artifact manifest, a wall-clock
//! timer ([`timer`]), a fixed-width table printer ([`table`]) used by the
//! experiments harness, and a tiny seeded property-testing loop ([`prop`])
//! standing in for `proptest`.

pub mod json;
pub mod prop;
pub mod table;
pub mod timer;

/// Mean of a slice (0.0 for empty — callers guard length).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation of a slice.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Max absolute difference between two equal-length slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "max_abs_diff: length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// L2 norm of a slice.
pub fn l2_norm(xs: &[f64]) -> f64 {
    xs.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Relative L2 error ‖a−b‖ / max(‖b‖, eps).
pub fn rel_l2_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt();
    num / l2_norm(b).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn diffs_and_norms() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert!(rel_l2_err(&[1.0, 0.0], &[1.0, 0.0]) == 0.0);
        assert!((rel_l2_err(&[2.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn max_abs_diff_length_mismatch_panics() {
        max_abs_diff(&[1.0], &[1.0, 2.0]);
    }
}
