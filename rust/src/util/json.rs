//! Minimal recursive-descent JSON parser — the single consumer of the AOT
//! `artifacts/manifest.json` schema (see `python/compile/aot.py`).
//!
//! Deliberately small: full JSON value model, UTF-8 strings with the
//! standard escapes, f64 numbers.  No serialization beyond what the
//! experiments harness needs ([`Value::render`]), no datetime, no comments.
//! Substitutes for `serde_json`, which the offline vendor set lacks.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a complete JSON document; trailing whitespace allowed.
    pub fn parse(src: &str) -> Result<Value, ParseError> {
        let mut p = Parser::new(src);
        let v = p.value()?;
        p.skip_ws();
        if p.pos < p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Compact single-line rendering (used when experiments emit JSON rows).
    pub fn render(&self) -> String {
        match self {
            Value::Null => "null".into(),
            Value::Bool(b) => b.to_string(),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            Value::Str(s) => render_string(s),
            Value::Arr(a) => {
                let inner: Vec<String> = a.iter().map(Value::render).collect();
                format!("[{}]", inner.join(","))
            }
            Value::Obj(m) => {
                let inner: Vec<String> = m
                    .iter()
                    .map(|(k, v)| format!("{}:{}", render_string(k), v.render()))
                    .collect();
                format!("{{{}}}", inner.join(","))
            }
        }
    }
}

fn render_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Parse failure with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(src: &'a str) -> Self {
        Parser { bytes: src.as_bytes(), pos: 0 }
    }

    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), offset: self.pos }
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8, what: &str) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(what))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{', "expected '{'")?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':', "expected ':' after object key")?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[', "expected '['")?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"', "expected '\"'")?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00));
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            match ch {
                                Some(c) => out.push(c),
                                None => return Err(self.err("invalid \\u escape")),
                            }
                            continue; // hex4 already advanced
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return Err(self.err("invalid UTF-8")),
                    }
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(Value::parse("3.5").unwrap(), Value::Num(3.5));
        assert_eq!(Value::parse("-2e3").unwrap(), Value::Num(-2000.0));
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn escapes_and_unicode() {
        assert_eq!(
            Value::parse(r#""a\nb\t\"c\" é""#).unwrap(),
            Value::Str("a\nb\t\"c\" é".into())
        );
        // surrogate pair: U+1F600
        assert_eq!(
            Value::parse(r#""😀""#).unwrap(),
            Value::Str("😀".into())
        );
    }

    #[test]
    fn nested_document() {
        let doc = r#"{"format":1,"artifacts":[{"name":"a","shape":[2,3],"ok":true},{"name":"b","shape":[],"ok":null}]}"#;
        let v = Value::parse(doc).unwrap();
        assert_eq!(v.get("format").and_then(Value::as_usize), Some(1));
        let arts = v.get("artifacts").and_then(Value::as_arr).unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[0].get("name").and_then(Value::as_str), Some("a"));
        assert_eq!(
            arts[0].get("shape").and_then(Value::as_arr).unwrap().len(),
            2
        );
        assert_eq!(arts[1].get("ok"), Some(&Value::Null));
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "\"unterminated", "1 2", "{\"a\" 1}",
            "[1 2]", "nul",
        ] {
            assert!(Value::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn round_trip_render() {
        let doc = r#"{"b":[1,2.5,"x"],"a":true}"#;
        let v = Value::parse(doc).unwrap();
        let rendered = v.render();
        assert_eq!(Value::parse(&rendered).unwrap(), v);
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(Value::parse(&s).is_ok());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Value::parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(Value::parse("{}").unwrap(), Value::Obj(Default::default()));
    }
}
