//! Tiny seeded property-testing loop — offline substitute for `proptest`.
//!
//! A property runs `cases` times against inputs drawn from a seeded [`Rng`]
//! (deterministic across runs).  On failure the failing case index and seed
//! are reported so the case replays exactly.  No shrinking — cases are kept
//! small by construction instead.

use crate::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 128, seed: 0xC0FFEE }
    }
}

/// Run `prop(case_rng, case_index)`; panics with replay info on failure.
pub fn for_all(cfg: PropConfig, mut prop: impl FnMut(&mut Rng, usize)) {
    let mut master = Rng::seed_from(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = master.next_u64();
        let mut rng = Rng::seed_from(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case)
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case}/{} (case_seed={case_seed:#x}, master_seed={:#x}): {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Shorthand with the default config.
pub fn quick(prop: impl FnMut(&mut Rng, usize)) {
    for_all(PropConfig::default(), prop);
}

/// Draw a random vector of length n with entries ~ N(0, scale).
pub fn normal_vec(rng: &mut Rng, n: usize, scale: f64) -> Vec<f64> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        quick(|rng, _| {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn reports_failing_case() {
        let res = std::panic::catch_unwind(|| {
            for_all(PropConfig { cases: 10, seed: 1 }, |rng, _| {
                assert!(rng.uniform() < 2.0); // passes
                assert!(false, "forced failure");
            })
        });
        let err = res.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<not a String panic>".into());
        assert!(msg.contains("property failed at case 0"), "{msg}");
    }

    #[test]
    fn deterministic_inputs() {
        let mut seen = Vec::new();
        for_all(PropConfig { cases: 5, seed: 42 }, |rng, _| {
            seen.push(rng.next_u64());
        });
        let mut again = Vec::new();
        for_all(PropConfig { cases: 5, seed: 42 }, |rng, _| {
            again.push(rng.next_u64());
        });
        assert_eq!(seen, again);
    }
}
