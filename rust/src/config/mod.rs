//! Typed configuration for the coordinator — the knobs a deployment would
//! set in one place, validated before anything runs.
//!
//! (The offline vendor set has no serde/toml; the CLI maps flags onto this
//! struct directly, and [`FitConfig::from_kv_pairs`] parses simple
//! `key=value` config files so runs remain scriptable.)

use anyhow::{bail, Context, Result};

use crate::mapreduce::{EngineConfig, FaultPlan, JobCosts};
use crate::solver::cd::CdSettings;
use crate::solver::penalty::Penalty;
use crate::stats::simd::KernelMode;

/// Everything Algorithm 1 needs.
#[derive(Debug, Clone, Copy)]
pub struct FitConfig {
    /// penalty family (elastic-net mixing α)
    pub penalty: Penalty,
    /// number of CV folds k (paper's rule of thumb: 5 or 10)
    pub folds: usize,
    /// λ grid size
    pub n_lambdas: usize,
    /// λ_min/λ_max ratio (0 ⇒ auto: 1e-3 if n > p else 1e-2)
    pub lambda_ratio: f64,
    /// coordinate-descent settings
    pub cd: CdSettings,
    /// mapper pool size
    pub workers: usize,
    /// rows per input split handed to one map task
    pub split_rows: usize,
    /// row-block size b for the *tiled* statistics job (rows of the packed
    /// z-triangle, d = p+1): 0 ⇒ untiled (one O(d²) triangle per fold
    /// reduce key); b > 0 ⇒ the reduce is keyed by `(fold, panel)`, no
    /// shuffle payload or merge slot exceeds O(d·b), and the driver keeps
    /// the panels resident end-to-end (fold complements, Grams and CD/ridge
    /// solves all panel-backed) — bit-identical output at every block size
    /// (oversized b degenerates to one panel)
    pub gram_block: usize,
    /// resident budget in bytes for the driver's panel store (tiled path
    /// only, i.e. requires `gram_block > 0`): 0 ⇒ unbounded in-memory
    /// residency ([`crate::store::MemStore`]); > 0 ⇒ merged `(fold, panel)`
    /// statistics retire into a spill-to-disk store
    /// ([`crate::store::SpillStore`]) whose resident panels never exceed
    /// max(budget, one panel) — leader memory is O(d·b · panels-in-flight)
    /// instead of O(k·d²), and the fit output is bit-identical at every
    /// budget (asserted in `tests/integration.rs`)
    pub store_budget_bytes: usize,
    /// screen-then-fit threshold: when p exceeds this, the driver defaults
    /// to SIS screening (`solver::screen`, m = min(n/log n, threshold)) and
    /// fits the penalized model + CV on the m×m sub-Gram gathered straight
    /// from the statistics — the paper's §4 envelope for p beyond the
    /// Gram-in-memory ceiling.  0 ⇒ never screen automatically.
    pub screen_auto: usize,
    /// sparse-row ingest: route rows through the nonzero-aware scatter
    /// kernels (`rank1_sparse`/`rank4_sparse`) — map arithmetic follows
    /// the touched-column union instead of O(d²) per chunk, and on the
    /// tiled path all-zero panels ship as O(d) markers
    /// (`JobMetrics::panels_skipped`).  Bit-identical output to the dense
    /// path on the same data at any setting of the other knobs.
    pub sparse: bool,
    /// spill-store readahead: when the panel store spills
    /// (`store_budget_bytes > 0`), a background prefetcher loads upcoming
    /// panels along the driver's deterministic access plan ahead of
    /// compute.  Purely an optimization — output is bit-identical either
    /// way and the residency bound is unchanged (`--no-prefetch` for A/B)
    pub prefetch: bool,
    /// scatter microkernel selection ([`crate::stats::simd`]): `Auto`
    /// (default) uses the SIMD kernel when the CPU supports it, `Scalar` /
    /// `Simd` force one side — both produce bit-identical statistics; the
    /// override exists for A/B benches and the bit-identity tests
    pub kernel: KernelMode,
    /// out-of-process worker runtime: number of worker *processes* to
    /// supervise (0 ⇒ the default in-process thread pool).  Requires the
    /// tiled statistics path (`gram_block > 0`) — task payloads travel as
    /// encoded panels.  The fit output is bit-identical to the in-process
    /// pool at every process count (asserted in `tests/proc_workers.rs`).
    pub proc_workers: usize,
    /// worker heartbeat period in ms for the process runtime (0 disables
    /// heartbeat supervision)
    pub heartbeat_ms: u64,
    /// per-attempt task deadline in ms for the process runtime (0 disables
    /// deadlines)
    pub task_deadline_ms: u64,
    /// salt for the random fold assignment (Algorithm 1 line 4)
    pub seed: u64,
    /// modeled cluster scheduling costs
    pub costs: JobCosts,
    /// fault injection (tests/chaos runs)
    pub fault: FaultPlan,
}

impl Default for FitConfig {
    fn default() -> Self {
        FitConfig {
            penalty: Penalty::lasso(),
            folds: 10,
            n_lambdas: 50,
            lambda_ratio: 0.0,
            cd: CdSettings::default(),
            workers: std::thread::available_parallelism()
                .map(|v| v.get())
                .unwrap_or(4),
            split_rows: 65_536,
            gram_block: 0,
            store_budget_bytes: 0,
            screen_auto: 4096,
            sparse: false,
            prefetch: true,
            kernel: KernelMode::Auto,
            proc_workers: 0,
            heartbeat_ms: 50,
            task_deadline_ms: 30_000,
            seed: 0x5EED,
            costs: JobCosts::zero(),
            fault: FaultPlan::none(),
        }
    }
}

impl FitConfig {
    pub fn with_penalty(mut self, penalty: Penalty) -> Self {
        self.penalty = penalty;
        self
    }

    pub fn with_folds(mut self, k: usize) -> Self {
        self.folds = k;
        self
    }

    pub fn with_workers(mut self, w: usize) -> Self {
        self.workers = w;
        self
    }

    pub fn with_lambdas(mut self, n: usize) -> Self {
        self.n_lambdas = n;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Row-block size for the tiled statistics job (0 ⇒ untiled).
    pub fn with_gram_block(mut self, b: usize) -> Self {
        self.gram_block = b;
        self
    }

    /// Panel-store resident budget in bytes (0 ⇒ unbounded in-memory;
    /// requires `gram_block > 0` when nonzero).
    pub fn with_store_budget(mut self, bytes: usize) -> Self {
        self.store_budget_bytes = bytes;
        self
    }

    /// Screen-then-fit threshold on p (0 ⇒ never screen automatically).
    pub fn with_screen_auto(mut self, threshold: usize) -> Self {
        self.screen_auto = threshold;
        self
    }

    /// Out-of-process worker count (0 ⇒ in-process thread pool; nonzero
    /// requires `gram_block > 0`).
    pub fn with_proc_workers(mut self, n: usize) -> Self {
        self.proc_workers = n;
        self
    }

    /// Sparse-row ingest (nonzero-aware scatter kernels + empty-panel
    /// shuffle suppression on the tiled path).
    pub fn with_sparse(mut self, on: bool) -> Self {
        self.sparse = on;
        self
    }

    /// Spill-store readahead (`false` ⇒ demand loads only).
    pub fn with_prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// Scatter microkernel selection (`Auto` / `Scalar` / `Simd`).
    pub fn with_kernel(mut self, mode: KernelMode) -> Self {
        self.kernel = mode;
        self
    }

    /// Validate invariants that would otherwise fail deep inside a job.
    pub fn validate(&self) -> Result<()> {
        if self.folds < 2 {
            bail!("folds must be >= 2 (got {})", self.folds);
        }
        if self.folds > 1000 {
            bail!("folds = {} is unreasonable (paper's rule of thumb: 5-10)", self.folds);
        }
        if self.n_lambdas == 0 {
            bail!("need at least one lambda");
        }
        if !(self.lambda_ratio == 0.0 || (0.0..1.0).contains(&self.lambda_ratio)) {
            bail!("lambda_ratio must be 0 (auto) or in (0,1)");
        }
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.split_rows == 0 {
            bail!("split_rows must be >= 1");
        }
        if self.cd.tol <= 0.0 || self.cd.max_sweeps == 0 {
            bail!("cd settings degenerate");
        }
        if self.store_budget_bytes > 0 && self.gram_block == 0 {
            bail!(
                "store_budget_bytes requires the tiled statistics path \
                 (set gram_block > 0)"
            );
        }
        if self.proc_workers > 0 && self.gram_block == 0 {
            bail!(
                "proc_workers requires the tiled statistics path \
                 (set gram_block > 0): task payloads travel as encoded panels"
            );
        }
        Ok(())
    }

    /// Engine view of this config.
    pub fn engine(&self) -> EngineConfig {
        EngineConfig {
            workers: self.workers,
            costs: self.costs,
            fault: self.fault,
            ..Default::default()
        }
    }

    /// Parse `key=value` lines (# comments allowed) over the defaults —
    /// the minimal config-file format the CLI accepts via `--config`.
    pub fn from_kv_pairs(text: &str) -> Result<Self> {
        let mut cfg = FitConfig::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key=value", lineno + 1))?;
            let (key, val) = (key.trim(), val.trim());
            match key {
                "penalty" => {
                    cfg.penalty = match val {
                        "lasso" => Penalty::lasso(),
                        "ridge" => Penalty::ridge(),
                        other => {
                            let a: f64 = other
                                .strip_prefix("elastic_net:")
                                .with_context(|| format!("unknown penalty {other:?}"))?
                                .parse()?;
                            Penalty::elastic_net(a)
                        }
                    }
                }
                "folds" => cfg.folds = val.parse()?,
                "n_lambdas" => cfg.n_lambdas = val.parse()?,
                "lambda_ratio" => cfg.lambda_ratio = val.parse()?,
                "workers" => cfg.workers = val.parse()?,
                "split_rows" => cfg.split_rows = val.parse()?,
                "gram_block" => cfg.gram_block = val.parse()?,
                "store_budget_bytes" => cfg.store_budget_bytes = val.parse()?,
                "screen_auto" => cfg.screen_auto = val.parse()?,
                "sparse" => cfg.sparse = val.parse()?,
                "prefetch" => cfg.prefetch = val.parse()?,
                "kernel" => {
                    cfg.kernel = KernelMode::parse(val)
                        .with_context(|| format!("unknown kernel mode {val:?} (auto|scalar|simd)"))?
                }
                "proc_workers" => cfg.proc_workers = val.parse()?,
                "heartbeat_ms" => cfg.heartbeat_ms = val.parse()?,
                "task_deadline_ms" => cfg.task_deadline_ms = val.parse()?,
                "seed" => cfg.seed = val.parse()?,
                "tol" => cfg.cd.tol = val.parse()?,
                "max_sweeps" => cfg.cd.max_sweeps = val.parse()?,
                other => bail!("unknown config key {other:?}"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        FitConfig::default().validate().unwrap();
    }

    #[test]
    fn builders_chain() {
        let c = FitConfig::default()
            .with_penalty(Penalty::ridge())
            .with_folds(5)
            .with_workers(2)
            .with_lambdas(10)
            .with_seed(7)
            .with_sparse(true);
        assert!(c.penalty.is_ridge());
        assert_eq!((c.folds, c.workers, c.n_lambdas, c.seed), (5, 2, 10, 7));
        assert!(c.sparse);
        assert!(!FitConfig::default().sparse, "sparse ingest is opt-in");
    }

    #[test]
    fn validation_catches_bad_values() {
        assert!(FitConfig { folds: 1, ..Default::default() }.validate().is_err());
        assert!(FitConfig { n_lambdas: 0, ..Default::default() }.validate().is_err());
        assert!(FitConfig { workers: 0, ..Default::default() }.validate().is_err());
        assert!(FitConfig { lambda_ratio: 2.0, ..Default::default() }.validate().is_err());
        assert!(FitConfig { split_rows: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn kv_parsing() {
        let cfg = FitConfig::from_kv_pairs(
            "# a comment\npenalty = elastic_net:0.5\nfolds=5\nworkers = 3\nseed=42\ngram_block=16\nstore_budget_bytes=4096\nscreen_auto=0\nsparse=true\n",
        )
        .unwrap();
        assert_eq!(cfg.penalty.alpha, 0.5);
        assert_eq!(cfg.folds, 5);
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.gram_block, 16);
        assert_eq!(cfg.store_budget_bytes, 4096);
        assert_eq!(cfg.screen_auto, 0, "screen-auto can be disabled");
        assert!(cfg.sparse, "sparse parses from kv");
        assert_eq!(FitConfig::default().gram_block, 0, "tiling is opt-in");
        assert_eq!(FitConfig::default().store_budget_bytes, 0, "spilling is opt-in");
        assert!(FitConfig::default().screen_auto > 0, "screening is the default at large p");
        assert!(FitConfig::from_kv_pairs("nonsense").is_err());
        assert!(FitConfig::from_kv_pairs("folds=1").is_err());
        assert!(FitConfig::from_kv_pairs("wat=1").is_err());
        assert!(FitConfig::from_kv_pairs("penalty=banana").is_err());
    }

    #[test]
    fn proc_workers_require_the_tiled_path_and_parse_from_kv() {
        let err = FitConfig { proc_workers: 4, ..Default::default() }.validate().unwrap_err();
        assert!(format!("{err:#}").contains("gram_block"), "{err:#}");
        FitConfig { proc_workers: 4, gram_block: 8, ..Default::default() }.validate().unwrap();
        let cfg = FitConfig::from_kv_pairs(
            "gram_block=4\nproc_workers=2\nheartbeat_ms=25\ntask_deadline_ms=5000\n",
        )
        .unwrap();
        assert_eq!(cfg.proc_workers, 2);
        assert_eq!(cfg.heartbeat_ms, 25);
        assert_eq!(cfg.task_deadline_ms, 5000);
        assert_eq!(FitConfig::default().proc_workers, 0, "process runtime is opt-in");
        let c = FitConfig::default().with_gram_block(4).with_proc_workers(3);
        assert_eq!(c.proc_workers, 3);
    }

    #[test]
    fn prefetch_and_kernel_knobs_default_and_parse() {
        let d = FitConfig::default();
        assert!(d.prefetch, "readahead is on by default");
        assert_eq!(d.kernel, KernelMode::Auto, "kernel dispatch is auto by default");
        let c = FitConfig::default()
            .with_prefetch(false)
            .with_kernel(KernelMode::Scalar);
        assert!(!c.prefetch);
        assert_eq!(c.kernel, KernelMode::Scalar);
        let cfg = FitConfig::from_kv_pairs("prefetch=false\nkernel=simd\n").unwrap();
        assert!(!cfg.prefetch);
        assert_eq!(cfg.kernel, KernelMode::Simd);
        let err = FitConfig::from_kv_pairs("kernel=banana").unwrap_err();
        assert!(format!("{err:#}").contains("kernel mode"), "{err:#}");
    }

    #[test]
    fn store_budget_requires_the_tiled_path() {
        // a budget without panels to spill is a config error, by name
        let err = FitConfig { store_budget_bytes: 1024, ..Default::default() }
            .validate()
            .unwrap_err();
        assert!(format!("{err:#}").contains("gram_block"), "{err:#}");
        assert!(FitConfig::from_kv_pairs("store_budget_bytes=1024").is_err());
        FitConfig { store_budget_bytes: 1024, gram_block: 8, ..Default::default() }
            .validate()
            .unwrap();
        let c = FitConfig::default().with_gram_block(4).with_store_budget(2048);
        assert_eq!((c.gram_block, c.store_budget_bytes), (4, 2048));
    }
}
