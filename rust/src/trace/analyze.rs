//! Post-run trace analysis: per-phase duration histograms, skew ratios,
//! straggler tables and the merge-tree critical path.
//!
//! Everything here is pure arithmetic over an event slice — deterministic
//! given the events, integer-indexed percentiles (no interpolation), and
//! rendered through [`crate::util::table`] so `fit --trace-summary` and
//! the bench harness print the same shapes that land in
//! `BENCH_gram_tiled.json`.

use std::collections::BTreeMap;

use crate::util::json::Value;
use crate::util::table::{sig, Table};
use crate::util::timer::fmt_secs;

use super::TraceEvent;

/// Duration summary of one `(phase, name)` span population.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseStat {
    pub phase: String,
    pub name: String,
    pub count: usize,
    pub total_us: u64,
    pub median_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

impl PhaseStat {
    /// Skew ratio p99/median — 1.0 means perfectly even task durations;
    /// large values mean a straggling tail.  1.0 when the median is 0.
    pub fn skew(&self) -> f64 {
        if self.median_us == 0 {
            1.0
        } else {
            self.p99_us as f64 / self.median_us as f64
        }
    }
}

/// The post-run analysis rendered by `fit --trace-summary`.
#[derive(Debug, Clone, Default)]
pub struct Analysis {
    /// one row per `(phase, name)` with at least one span event
    pub phases: Vec<PhaseStat>,
    /// longest map span + Σ over merge-tree levels of that level's longest
    /// merge — the serial floor of the job under infinite workers
    pub critical_path_us: u64,
    /// top span events by duration, deterministically tie-broken
    pub stragglers: Vec<TraceEvent>,
    /// total events analyzed (spans + instants)
    pub events: usize,
}

/// Integer-indexed percentile of an ascending-sorted slice (nearest-rank,
/// no interpolation — deterministic for any input).
fn percentile(sorted: &[u64], pct: u64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as u64 * pct) / 100;
    sorted[idx as usize]
}

/// How many stragglers the table keeps.
const TOP_N: usize = 8;

/// Analyze an event stream (order-insensitive; instants contribute to the
/// event count but not to duration statistics).
pub fn analyze(events: &[TraceEvent]) -> Analysis {
    let mut groups: BTreeMap<(String, String), Vec<u64>> = BTreeMap::new();
    for ev in events {
        if ev.dur_us > 0 {
            groups
                .entry((ev.phase.clone(), ev.name.clone()))
                .or_default()
                .push(ev.dur_us);
        }
    }
    let mut phases = Vec::with_capacity(groups.len());
    for ((phase, name), mut durs) in groups {
        durs.sort_unstable();
        phases.push(PhaseStat {
            phase,
            name,
            count: durs.len(),
            total_us: durs.iter().sum(),
            median_us: percentile(&durs, 50),
            p90_us: percentile(&durs, 90),
            p99_us: percentile(&durs, 99),
            max_us: *durs.last().unwrap(),
        });
    }

    // critical path through the merge tree: the longest map leaf, then the
    // longest merge at every level (levels run in parallel within
    // themselves but serially with respect to each other)
    let longest_map = events
        .iter()
        .filter(|e| e.phase == "engine" && e.name == "map")
        .map(|e| e.dur_us)
        .max()
        .unwrap_or(0);
    let mut level_max: BTreeMap<u64, u64> = BTreeMap::new();
    for ev in events {
        if ev.phase == "engine" && ev.name == "merge" {
            if let Some(lvl) = parse_merge_level(&ev.key) {
                let slot = level_max.entry(lvl).or_insert(0);
                *slot = (*slot).max(ev.dur_us);
            }
        }
    }
    let critical_path_us = longest_map + level_max.values().sum::<u64>();

    let mut spans: Vec<&TraceEvent> = events.iter().filter(|e| e.dur_us > 0).collect();
    spans.sort_by(|a, b| {
        b.dur_us
            .cmp(&a.dur_us)
            .then_with(|| (&a.phase, &a.key, &a.name, a.worker).cmp(&(&b.phase, &b.key, &b.name, b.worker)))
    });
    let stragglers = spans.into_iter().take(TOP_N).cloned().collect();

    Analysis { phases, critical_path_us, stragglers, events: events.len() }
}

/// `"L2.n5"` → `Some(2)`; anything else → `None`.
fn parse_merge_level(key: &str) -> Option<u64> {
    let rest = key.strip_prefix('L')?;
    let (lvl, _) = rest.split_once('.')?;
    lvl.parse().ok()
}

impl Analysis {
    /// Skew ratio of one `(phase, name)` population, if it was observed.
    pub fn skew_of(&self, phase: &str, name: &str) -> Option<f64> {
        self.phases
            .iter()
            .find(|p| p.phase == phase && p.name == name)
            .map(PhaseStat::skew)
    }

    /// The headline skew — map-task spans if present, else the worst skew
    /// across all populations, else 1.0 (used by the bench JSON).
    pub fn map_skew(&self) -> f64 {
        self.skew_of("engine", "map").unwrap_or_else(|| {
            self.phases.iter().map(|p| p.skew()).fold(1.0, f64::max)
        })
    }

    /// Render the phase-histogram and straggler tables (the
    /// `fit --trace-summary` body).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut t = Table::new(vec![
            "phase", "event", "count", "total", "median", "p90", "p99", "max", "skew",
        ]);
        for p in &self.phases {
            t.row(vec![
                p.phase.clone(),
                p.name.clone(),
                format!("{}", p.count),
                fmt_secs(p.total_us as f64 / 1e6),
                fmt_secs(p.median_us as f64 / 1e6),
                fmt_secs(p.p90_us as f64 / 1e6),
                fmt_secs(p.p99_us as f64 / 1e6),
                fmt_secs(p.max_us as f64 / 1e6),
                sig(p.skew(), 3),
            ]);
        }
        out.push_str(&t.render());
        out.push_str(&format!(
            "\ncritical path (longest map + per-level longest merges): {}\n",
            fmt_secs(self.critical_path_us as f64 / 1e6)
        ));
        if !self.stragglers.is_empty() {
            out.push_str("\ntop stragglers:\n");
            let mut s = Table::new(vec!["phase", "event", "key", "worker", "dur", "n"]);
            for ev in &self.stragglers {
                s.row(vec![
                    ev.phase.clone(),
                    ev.name.clone(),
                    ev.key.clone(),
                    format!("{}", ev.worker),
                    fmt_secs(ev.dur_us as f64 / 1e6),
                    format!("{}", ev.n),
                ]);
            }
            out.push_str(&s.render());
        }
        out
    }

    /// Machine-readable form for `BENCH_gram_tiled.json` and friends.
    pub fn to_json(&self) -> Value {
        let mut phases = Vec::with_capacity(self.phases.len());
        for p in &self.phases {
            let mut m = std::collections::BTreeMap::new();
            m.insert("phase".to_string(), Value::Str(p.phase.clone()));
            m.insert("event".to_string(), Value::Str(p.name.clone()));
            m.insert("count".to_string(), Value::Num(p.count as f64));
            m.insert("total_us".to_string(), Value::Num(p.total_us as f64));
            m.insert("median_us".to_string(), Value::Num(p.median_us as f64));
            m.insert("p90_us".to_string(), Value::Num(p.p90_us as f64));
            m.insert("p99_us".to_string(), Value::Num(p.p99_us as f64));
            m.insert("max_us".to_string(), Value::Num(p.max_us as f64));
            m.insert("skew".to_string(), Value::Num(p.skew()));
            phases.push(Value::Obj(m));
        }
        let mut root = std::collections::BTreeMap::new();
        root.insert("events".to_string(), Value::Num(self.events as f64));
        root.insert("critical_path_us".to_string(), Value::Num(self.critical_path_us as f64));
        root.insert("map_skew".to_string(), Value::Num(self.map_skew()));
        root.insert("phases".to_string(), Value::Arr(phases));
        Value::Obj(root)
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::super::TraceEvent;
    use super::*;

    fn ev(phase: &str, name: &str, key: &str, worker: u64, dur: u64) -> TraceEvent {
        TraceEvent {
            phase: phase.into(),
            name: name.into(),
            key: key.into(),
            worker,
            seq: 0,
            start_us: 0,
            dur_us: dur,
            n: 0,
        }
    }

    fn fixture() -> Vec<TraceEvent> {
        vec![
            // 4 map spans, one straggler
            ev("engine", "map", "t0.a0", 0, 100),
            ev("engine", "map", "t1.a0", 1, 110),
            ev("engine", "map", "t2.a0", 2, 105),
            ev("engine", "map", "t3.a0", 3, 1000),
            // two merge levels: max 50 at L1, max 30 at L0
            ev("engine", "merge", "L1.n2", 0, 50),
            ev("engine", "merge", "L1.n3", 1, 40),
            ev("engine", "merge", "L0.n1", 0, 30),
            // an instant contributes to the count only
            TraceEvent { dur_us: 0, ..ev("proc", "spawn", "w0", 0, 0) },
        ]
    }

    #[test]
    fn percentiles_are_nearest_rank() {
        let xs = [10, 20, 30, 40];
        assert_eq!(percentile(&xs, 50), 20);
        assert_eq!(percentile(&xs, 99), 30, "(n-1)*99/100 = 2 for n = 4");
        assert_eq!(percentile(&xs, 100), 40);
        assert_eq!(percentile(&[], 50), 0);
    }

    #[test]
    fn analysis_computes_skew_and_critical_path() {
        let a = analyze(&fixture());
        assert_eq!(a.events, 8);
        let map = a.phases.iter().find(|p| p.name == "map").unwrap();
        assert_eq!(map.count, 4);
        assert_eq!(map.median_us, 105);
        assert_eq!(map.max_us, 1000);
        assert!(map.skew() > 1.0);
        // 1000 (longest map) + 50 (L1) + 30 (L0)
        assert_eq!(a.critical_path_us, 1080);
        // straggler table leads with the slow map task
        assert_eq!(a.stragglers[0].key, "t3.a0");
        assert!(a.skew_of("engine", "merge").is_some());
        assert!(a.skew_of("engine", "nope").is_none());
        assert!(a.map_skew() > 1.0);
    }

    #[test]
    fn analysis_is_emission_order_insensitive() {
        let mut rev = fixture();
        rev.reverse();
        let a = analyze(&fixture());
        let b = analyze(&rev);
        assert_eq!(a.phases, b.phases);
        assert_eq!(a.critical_path_us, b.critical_path_us);
        assert_eq!(
            a.stragglers.iter().map(|e| e.key.clone()).collect::<Vec<_>>(),
            b.stragglers.iter().map(|e| e.key.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn render_and_json_carry_the_tables() {
        let a = analyze(&fixture());
        let s = a.render();
        assert!(s.contains("critical path"));
        assert!(s.contains("top stragglers"));
        assert!(s.contains("t3.a0"));
        assert!(s.contains("skew"));
        let j = a.to_json().render();
        let parsed = Value::parse(&j).unwrap();
        assert!(parsed.get("map_skew").unwrap().as_f64().unwrap() > 1.0);
        assert_eq!(parsed.get("critical_path_us").unwrap().as_usize().unwrap(), 1080);
        assert!(!parsed.get("phases").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn empty_stream_is_benign() {
        let a = analyze(&[]);
        assert_eq!(a.critical_path_us, 0);
        assert!(a.phases.is_empty() && a.stragglers.is_empty());
        assert_eq!(a.map_skew(), 1.0);
        assert!(a.render().contains("critical path"));
    }
}
