//! Deterministic-safe tracing — the observability layer under the engine,
//! supervisor, stores, driver, CV sweep and CD solver.
//!
//! Every layer emits [`TraceEvent`]s into a process-global sink of
//! per-worker bounded ring buffers (append goes through the
//! [`crate::sync`] shim's `lock_named`, sharded by worker lane so the hot
//! paths never contend on one mutex).  Wall-clock timestamps are **payload
//! only**: they ride along for humans and Perfetto, but no key, merge
//! order or payload byte is ever derived from them — the
//! `wallclock-outside-trace` detlint rule fences `Instant::now` into
//! `util/timer.rs` and this module so time cannot leak back into keyed
//! logic.  Tracing is observe-only by contract: `tests/trace_observe.rs`
//! pins the fit bit-identical with tracing off / on / exporting.
//!
//! ## Event taxonomy
//!
//! | phase    | names                                            | key shape      |
//! |----------|--------------------------------------------------|----------------|
//! | `engine` | `map`, `crash`, `flush`, `merge`, `retire`       | `t3.a0`, `L2.n5`, `w2` |
//! | `proc`   | `spawn`, `hello`, `assign`, `output`, `task-failed`, `deadline`, `hb-silent`, `kill`, `requeue`, `respawn` | `w2`, `t3.a1` |
//! | `store`  | `admit`, `evict`, `spill-write`, `spill-read`, `read-retry`, `prefetch-issue`, `prefetch-hit`, `prefetch-wasted` | `f1.p7` |
//! | `driver` | `stats-job`, `standardize`, `cv`, `screen`, `final-solve` | phase-specific |
//! | `cv`     | `cell`                                           | `f1.l12`       |
//! | `solver` | `cd`, `ridge`                                    | `l=0.031250`   |
//! | `kernel` | `dispatch`                                       | `auto`/`simd`/`scalar` |
//!
//! In proc mode a worker process drains its sink after every task and
//! ships the batch to the leader as a
//! [`TraceBatch`][crate::mapreduce::transport::Message::TraceBatch] frame
//! (same checksummed dialect as every other frame); the leader ingests the
//! batch into its own sink, so one `drain()` at export time sees the whole
//! fleet.
//!
//! ## Exporters
//!
//! * [`write_events`] — JSONL, one event per line, canonically ordered by
//!   `(phase, key, name, worker)` with `seq` reassigned to the canonical
//!   index.  Timestamps are ordinary fields, so two runs of the same fit
//!   diff clean except for the `start_us`/`dur_us` columns.
//! * [`write_chrome`] — Chrome trace-event JSON (`ph:"X"` spans,
//!   `ph:"i"` instants, one `tid` lane per worker), loadable in Perfetto
//!   or `chrome://tracing`.
//! * [`analyze`][mod@analyze] — post-run skew/straggler/critical-path
//!   analysis rendered by `fit --trace-summary` and the bench harness.
//!
//! Under `--cfg loom` the sink compiles to no-ops (loom's `Mutex` is not
//! usable outside a model run); the loom models never trace.

pub mod analyze;

use std::fmt;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

/// One trace event.  `dur_us == 0` marks an instant event; anything else
/// is a span.  `seq` breaks ties deterministically once events are
/// canonicalized — it is an occurrence index, not a timestamp.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// layer: `engine`, `proc`, `store`, `driver`, `cv`, `solver`, `kernel`
    pub phase: String,
    /// event name within the layer (see the module-level taxonomy table)
    pub name: String,
    /// deterministic key — task/attempt, tree node, panel, λ index …
    pub key: String,
    /// lane: engine worker index or proc worker id; leader-side events use 0
    pub worker: u64,
    /// canonical occurrence index (assigned by [`canonicalize`])
    pub seq: u64,
    /// wall-clock start, µs since the process trace epoch — payload only
    pub start_us: u64,
    /// span duration in µs; 0 for instant events — payload only
    pub dur_us: u64,
    /// free count payload: rows, sweeps, bytes, attempt …
    pub n: u64,
}

impl fmt::Display for TraceEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}/{} {} w{} n={} +{}µs {}µs",
            self.phase, self.name, self.key, self.worker, self.n, self.start_us, self.dur_us
        )
    }
}

// ---------------------------------------------------------------------------
// the process-global sink
// ---------------------------------------------------------------------------

#[cfg(not(loom))]
mod sink {
    use std::collections::VecDeque;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::OnceLock;
    use std::time::Instant;

    use crate::sync::{lock_named, Mutex};

    use super::TraceEvent;

    /// Worker lanes hash into this many independently locked buffers.
    const SHARDS: usize = 16;

    /// Ring capacity per shard — oldest events drop first, counted, so a
    /// pathological fit can never let the sink grow without bound.
    const SHARD_CAP: usize = 1 << 14;

    struct Sink {
        shards: Vec<Mutex<VecDeque<TraceEvent>>>,
        dropped: AtomicU64,
    }

    // process-global counters stay on std atomics by the same policy as
    // the spill-dir / socket-path counters (see crate::sync module docs)
    static ENABLED: AtomicBool = AtomicBool::new(false);
    static SEQ: AtomicU64 = AtomicU64::new(0);
    static SINK: OnceLock<Sink> = OnceLock::new();
    static EPOCH: OnceLock<Instant> = OnceLock::new();

    fn sink() -> &'static Sink {
        SINK.get_or_init(|| Sink {
            shards: (0..SHARDS).map(|_| Mutex::new(VecDeque::new())).collect(),
            dropped: AtomicU64::new(0),
        })
    }

    /// Turn event collection on or off, process-wide.  Off is the default
    /// and costs one relaxed atomic load per (guarded) call site.
    pub fn set_enabled(on: bool) {
        // pin the epoch the moment tracing first turns on, so start_us
        // offsets are comparable across the whole run
        if on {
            let _ = EPOCH.get_or_init(Instant::now);
        }
        ENABLED.store(on, Ordering::Relaxed);
    }

    pub fn enabled() -> bool {
        ENABLED.load(Ordering::Relaxed)
    }

    /// µs since the trace epoch (the first `set_enabled(true)` of the
    /// process).  Timestamp payload only — never feeds keyed logic.
    pub fn now_us() -> u64 {
        let epoch = EPOCH.get_or_init(Instant::now);
        epoch.elapsed().as_micros() as u64
    }

    /// Append one event (no-op while disabled).  Sharded by worker lane;
    /// the ring drops its oldest event when full.
    pub fn push(mut ev: TraceEvent) {
        if !enabled() {
            return;
        }
        ev.seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let s = sink();
        let mut ring = lock_named(&s.shards[(ev.worker as usize) % SHARDS], "trace ring");
        if ring.len() >= SHARD_CAP {
            ring.pop_front();
            s.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(ev);
    }

    /// Take every buffered event out of the sink, in emission (`seq`)
    /// order.  Used by workers to ship batches and by the leader at
    /// export time.
    pub fn drain() -> Vec<TraceEvent> {
        let s = sink();
        let mut out = Vec::new();
        for shard in &s.shards {
            out.extend(lock_named(shard, "trace ring").drain(..));
        }
        out.sort_by_key(|e| e.seq);
        out
    }

    /// Events dropped by full rings since process start.
    pub fn dropped() -> u64 {
        sink().dropped.load(Ordering::Relaxed)
    }
}

#[cfg(loom)]
mod sink {
    use super::TraceEvent;

    pub fn set_enabled(_on: bool) {}
    pub fn enabled() -> bool {
        false
    }
    pub fn now_us() -> u64 {
        0
    }
    pub fn push(_ev: TraceEvent) {}
    pub fn drain() -> Vec<TraceEvent> {
        Vec::new()
    }
    pub fn dropped() -> u64 {
        0
    }
}

pub use sink::{drain, dropped, enabled, now_us, set_enabled};

/// Emit a span event: `start_us` from an earlier [`now_us`], duration
/// computed here.  Call sites guard with [`enabled`] so key formatting
/// costs nothing while tracing is off.
pub fn emit_span(phase: &str, name: &str, key: String, worker: u64, start_us: u64, n: u64) {
    sink::push(TraceEvent {
        phase: phase.to_string(),
        name: name.to_string(),
        key,
        worker,
        seq: 0,
        start_us,
        dur_us: now_us().saturating_sub(start_us).max(1),
        n,
    });
}

/// Emit an instant event (duration 0).
pub fn emit_instant(phase: &str, name: &str, key: String, worker: u64, n: u64) {
    sink::push(TraceEvent {
        phase: phase.to_string(),
        name: name.to_string(),
        key,
        worker,
        seq: 0,
        start_us: now_us(),
        dur_us: 0,
        n,
    });
}

/// Ingest a batch shipped from a worker process (a decoded
/// `TraceBatch` payload): events re-enter this process's sink in batch
/// order, keeping their originating lane.
pub fn ingest(events: Vec<TraceEvent>) {
    for ev in events {
        sink::push(ev);
    }
}

// ---------------------------------------------------------------------------
// canonical ordering
// ---------------------------------------------------------------------------

/// Sort events into the canonical deterministic order — `(phase, key,
/// name, worker, seq)` — and reassign `seq` to the canonical index.  Two
/// runs of the same fit produce the same canonical stream except for the
/// timestamp payload fields.
pub fn canonicalize(events: &mut Vec<TraceEvent>) {
    events.sort_by(|a, b| {
        (&a.phase, &a.key, &a.name, a.worker, a.seq)
            .cmp(&(&b.phase, &b.key, &b.name, b.worker, b.seq))
    });
    for (i, ev) in events.iter_mut().enumerate() {
        ev.seq = i as u64;
    }
}

// ---------------------------------------------------------------------------
// binary codec (the TraceBatch wire payload)
// ---------------------------------------------------------------------------

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_u64(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    let end = *pos + 8;
    if end > bytes.len() {
        bail!("trace batch underrun: need {end} bytes, have {}", bytes.len());
    }
    let v = u64::from_le_bytes(bytes[*pos..end].try_into().unwrap());
    *pos = end;
    Ok(v)
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u64(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn get_str(bytes: &[u8], pos: &mut usize) -> Result<String> {
    let len = get_u64(bytes, pos)? as usize;
    let end = *pos + len;
    if end > bytes.len() {
        bail!("trace batch underrun: need {end} bytes, have {}", bytes.len());
    }
    let s = String::from_utf8(bytes[*pos..end].to_vec())
        .context("trace batch: string field is not UTF-8")?;
    *pos = end;
    Ok(s)
}

/// Encode a batch of events in the little-endian length-prefixed dialect
/// (the opaque payload of a `TraceBatch` frame).
pub fn encode_events(events: &[TraceEvent]) -> Vec<u8> {
    let mut b = Vec::new();
    put_u64(&mut b, events.len() as u64);
    for ev in events {
        put_str(&mut b, &ev.phase);
        put_str(&mut b, &ev.name);
        put_str(&mut b, &ev.key);
        put_u64(&mut b, ev.worker);
        put_u64(&mut b, ev.seq);
        put_u64(&mut b, ev.start_us);
        put_u64(&mut b, ev.dur_us);
        put_u64(&mut b, ev.n);
    }
    b
}

/// Decode a batch encoded by [`encode_events`]; every underrun or bad
/// string is a named error, never a panic.
pub fn decode_events(bytes: &[u8]) -> Result<Vec<TraceEvent>> {
    let mut pos = 0usize;
    let count = get_u64(bytes, &mut pos)? as usize;
    let mut out = Vec::with_capacity(count.min(1 << 20));
    for _ in 0..count {
        out.push(TraceEvent {
            phase: get_str(bytes, &mut pos)?,
            name: get_str(bytes, &mut pos)?,
            key: get_str(bytes, &mut pos)?,
            worker: get_u64(bytes, &mut pos)?,
            seq: get_u64(bytes, &mut pos)?,
            start_us: get_u64(bytes, &mut pos)?,
            dur_us: get_u64(bytes, &mut pos)?,
            n: get_u64(bytes, &mut pos)?,
        });
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// JSONL exporter
// ---------------------------------------------------------------------------

fn event_to_json(ev: &TraceEvent) -> Value {
    let mut m = std::collections::BTreeMap::new();
    m.insert("phase".to_string(), Value::Str(ev.phase.clone()));
    m.insert("name".to_string(), Value::Str(ev.name.clone()));
    m.insert("key".to_string(), Value::Str(ev.key.clone()));
    m.insert("worker".to_string(), Value::Num(ev.worker as f64));
    m.insert("seq".to_string(), Value::Num(ev.seq as f64));
    m.insert("start_us".to_string(), Value::Num(ev.start_us as f64));
    m.insert("dur_us".to_string(), Value::Num(ev.dur_us as f64));
    m.insert("n".to_string(), Value::Num(ev.n as f64));
    Value::Obj(m)
}

fn event_from_json(v: &Value) -> Result<TraceEvent> {
    let field = |k: &str| v.get(k).with_context(|| format!("trace JSONL: missing field {k:?}"));
    let s = |k: &str| -> Result<String> {
        Ok(field(k)?.as_str().with_context(|| format!("trace JSONL: field {k:?} not a string"))?.to_string())
    };
    let u = |k: &str| -> Result<u64> {
        let n = field(k)?.as_f64().with_context(|| format!("trace JSONL: field {k:?} not a number"))?;
        Ok(n as u64)
    };
    Ok(TraceEvent {
        phase: s("phase")?,
        name: s("name")?,
        key: s("key")?,
        worker: u("worker")?,
        seq: u("seq")?,
        start_us: u("start_us")?,
        dur_us: u("dur_us")?,
        n: u("n")?,
    })
}

/// Write events as JSONL: one canonical-ordered event per line.  The
/// canonical order is deterministic run-to-run; only the timestamp fields
/// (`start_us`/`dur_us`) differ between runs of the same fit.
pub fn write_events(path: &Path, events: &[TraceEvent]) -> Result<()> {
    let mut canon = events.to_vec();
    canonicalize(&mut canon);
    let mut out = String::new();
    for ev in &canon {
        out.push_str(&event_to_json(ev).render());
        out.push('\n');
    }
    fs::write(path, out).with_context(|| format!("write trace JSONL {path:?}"))
}

/// Read a JSONL trace back — the inverse of [`write_events`] for
/// canonicalized streams (`read_events(write_events(ev)) == ev`).
pub fn read_events(path: &Path) -> Result<Vec<TraceEvent>> {
    let text = fs::read_to_string(path).with_context(|| format!("read trace JSONL {path:?}"))?;
    let mut out = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Value::parse(line)
            .map_err(|e| anyhow::anyhow!("trace JSONL line {}: {e}", idx + 1))?;
        out.push(event_from_json(&v)?);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Chrome trace-event exporter
// ---------------------------------------------------------------------------

/// Render events as Chrome trace-event JSON (the Perfetto /
/// `chrome://tracing` format): spans are `ph:"X"` complete events with one
/// `tid` lane per worker, instants are `ph:"i"` thread-scoped marks, and
/// the deterministic key/count ride in `args`.
pub fn chrome_json(events: &[TraceEvent]) -> Value {
    let mut canon = events.to_vec();
    canonicalize(&mut canon);
    let mut arr = Vec::with_capacity(canon.len());
    for ev in &canon {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Value::Str(format!("{}/{} {}", ev.phase, ev.name, ev.key)));
        m.insert("cat".to_string(), Value::Str(ev.phase.clone()));
        m.insert("pid".to_string(), Value::Num(1.0));
        m.insert("tid".to_string(), Value::Num(ev.worker as f64));
        m.insert("ts".to_string(), Value::Num(ev.start_us as f64));
        if ev.dur_us > 0 {
            m.insert("ph".to_string(), Value::Str("X".to_string()));
            m.insert("dur".to_string(), Value::Num(ev.dur_us as f64));
        } else {
            m.insert("ph".to_string(), Value::Str("i".to_string()));
            m.insert("s".to_string(), Value::Str("t".to_string()));
        }
        let mut args = std::collections::BTreeMap::new();
        args.insert("key".to_string(), Value::Str(ev.key.clone()));
        args.insert("n".to_string(), Value::Num(ev.n as f64));
        args.insert("seq".to_string(), Value::Num(ev.seq as f64));
        m.insert("args".to_string(), Value::Obj(args));
        arr.push(Value::Obj(m));
    }
    let mut root = std::collections::BTreeMap::new();
    root.insert("traceEvents".to_string(), Value::Arr(arr));
    root.insert("displayTimeUnit".to_string(), Value::Str("ms".to_string()));
    Value::Obj(root)
}

/// Write the Chrome trace-event JSON file for [`chrome_json`].
pub fn write_chrome(path: &Path, events: &[TraceEvent]) -> Result<()> {
    fs::write(path, chrome_json(events).render())
        .with_context(|| format!("write Chrome trace {path:?}"))
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    fn ev(phase: &str, name: &str, key: &str, worker: u64, start: u64, dur: u64, n: u64) -> TraceEvent {
        TraceEvent {
            phase: phase.into(),
            name: name.into(),
            key: key.into(),
            worker,
            seq: 0,
            start_us: start,
            dur_us: dur,
            n,
        }
    }

    fn sample() -> Vec<TraceEvent> {
        vec![
            ev("engine", "map", "t1.a0", 2, 10, 40, 512),
            ev("engine", "map", "t0.a0", 1, 11, 35, 512),
            ev("engine", "merge", "L1.n2", 1, 60, 9, 2),
            ev("proc", "spawn", "w0", 0, 0, 0, 1),
            ev("store", "spill-write", "f0.p3", 0, 70, 5, 4096),
            ev("solver", "cd", "l=0.0313", 0, 90, 12, 17),
        ]
    }

    #[test]
    fn canonical_order_is_total_and_reassigns_seq() {
        let mut a = sample();
        let mut b = sample();
        b.reverse();
        canonicalize(&mut a);
        canonicalize(&mut b);
        assert_eq!(a, b, "canonical order is independent of emission order");
        for (i, ev) in a.iter().enumerate() {
            assert_eq!(ev.seq, i as u64);
        }
    }

    #[test]
    fn binary_codec_round_trips_bit_exact() {
        let mut events = sample();
        canonicalize(&mut events);
        let bytes = encode_events(&events);
        assert_eq!(decode_events(&bytes).unwrap(), events);
        // truncation anywhere is a named error, never a panic
        for cut in [0usize, 7, 8, 20, bytes.len() - 1] {
            assert!(decode_events(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn jsonl_round_trips_through_the_schema() {
        let dir = std::env::temp_dir().join(format!("plrmr-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let mut events = sample();
        canonicalize(&mut events);
        write_events(&path, &events).unwrap();
        let back = read_events(&path).unwrap();
        assert_eq!(back, events, "read_events(write_events(ev)) == ev");
        // every line parses standalone
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), events.len());
        for line in text.lines() {
            Value::parse(line).unwrap();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn jsonl_bytes_are_stable_for_identical_streams() {
        let dir = std::env::temp_dir().join(format!("plrmr-trace-stable-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (p1, p2) = (dir.join("a.jsonl"), dir.join("b.jsonl"));
        // emission order differs; canonical bytes must not
        let mut a = sample();
        let mut b = sample();
        b.rotate_left(3);
        a.iter_mut().for_each(|e| e.seq = 99);
        write_events(&p1, &a).unwrap();
        write_events(&p2, &b).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn chrome_export_is_well_formed_json_with_lanes() {
        let v = chrome_json(&sample());
        let rendered = v.render();
        let parsed = Value::parse(&rendered).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 6);
        let mut span_seen = false;
        let mut instant_seen = false;
        for e in evs {
            match e.get("ph").unwrap().as_str().unwrap() {
                "X" => {
                    span_seen = true;
                    assert!(e.get("dur").unwrap().as_f64().unwrap() > 0.0);
                }
                "i" => instant_seen = true,
                other => panic!("unexpected ph {other:?}"),
            }
            assert!(e.get("tid").is_some(), "one lane per worker");
        }
        assert!(span_seen && instant_seen);
    }

    #[test]
    fn sink_collects_and_drains_in_emission_order() {
        // the sink is process-global; drain whatever other tests left, run
        // our sequence, and filter to this test's marker phase
        set_enabled(true);
        let _ = drain();
        let t0 = now_us();
        emit_span("test-sink", "alpha", "k0".into(), 3, t0, 7);
        emit_instant("test-sink", "beta", "k1".into(), 5, 9);
        ingest(vec![ev("test-sink", "gamma", "k2", 8, 1, 2, 3)]);
        set_enabled(false);
        let got: Vec<TraceEvent> =
            drain().into_iter().filter(|e| e.phase == "test-sink").collect();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].name, "alpha");
        assert!(got[0].dur_us >= 1, "span durations are clamped positive");
        assert_eq!(got[1].name, "beta");
        assert_eq!(got[1].dur_us, 0);
        assert_eq!(got[2].worker, 8, "ingested events keep their lane");
        // disabled sink drops silently
        emit_instant("test-sink", "late", "k3".into(), 0, 0);
        assert!(drain().iter().all(|e| e.phase != "test-sink"));
    }
}
