//! Two-pass standardization of materialized data — the baseline-side twin
//! of the statistics-side standardization in [`crate::stats::suffstats`].
//!
//! Baselines are allowed to touch raw data (they do anyway — that is their
//! handicap); using the identical convention (center, unit population sd)
//! guarantees every system minimizes the same standardized objective.

use crate::data::dataset::Dataset;

/// Centered/scaled copies plus the transform metadata.
#[derive(Debug, Clone)]
pub struct Standardized {
    pub p: usize,
    pub n: usize,
    /// row-major n×p, centered and unit-sd columns (degenerate cols zeroed)
    pub xc: Vec<f64>,
    /// centered response y − ȳ
    pub yc: Vec<f64>,
    pub x_mean: Vec<f64>,
    /// population sd per column; 0 marks degenerate
    pub scale: Vec<f64>,
    pub y_mean: f64,
}

impl Standardized {
    pub fn from_dataset(data: &Dataset) -> Self {
        let (n, p) = (data.n(), data.p);
        assert!(n >= 2, "need at least 2 rows");
        let nf = n as f64;
        let mut x_mean = vec![0.0; p];
        for i in 0..n {
            let row = data.row(i);
            for j in 0..p {
                x_mean[j] += row[j];
            }
        }
        for m in &mut x_mean {
            *m /= nf;
        }
        let y_mean = data.y.iter().sum::<f64>() / nf;
        let mut var = vec![0.0; p];
        for i in 0..n {
            let row = data.row(i);
            for j in 0..p {
                let d = row[j] - x_mean[j];
                var[j] += d * d;
            }
        }
        let scale: Vec<f64> = var
            .iter()
            .map(|v| {
                let s = (v / nf).sqrt();
                if s > 0.0 {
                    s
                } else {
                    0.0
                }
            })
            .collect();
        let mut xc = vec![0.0; n * p];
        for i in 0..n {
            let row = data.row(i);
            for j in 0..p {
                xc[i * p + j] = if scale[j] > 0.0 {
                    (row[j] - x_mean[j]) / scale[j]
                } else {
                    0.0
                };
            }
        }
        let yc: Vec<f64> = data.y.iter().map(|y| y - y_mean).collect();
        Standardized { p, n, xc, yc, x_mean, scale, y_mean }
    }

    /// Column j as a strided view helper.
    #[inline]
    pub fn col(&self, j: usize, i: usize) -> f64 {
        self.xc[i * self.p + j]
    }

    /// Back-transform standardized coefficients to original scale (eq. 4).
    pub fn to_original_scale(&self, beta_std: &[f64]) -> (f64, Vec<f64>) {
        let beta: Vec<f64> = beta_std
            .iter()
            .zip(&self.scale)
            .map(|(b, d)| if *d > 0.0 { b / d } else { 0.0 })
            .collect();
        let alpha = self.y_mean
            - self
                .x_mean
                .iter()
                .zip(&beta)
                .map(|(m, b)| m * b)
                .sum::<f64>();
        (alpha, beta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::stats::SuffStats;

    #[test]
    fn matches_suffstats_standardization() {
        let d = generate(&SynthSpec::sparse_linear(500, 4, 0.5, 3));
        let std = Standardized::from_dataset(&d);
        let mut s = SuffStats::new(4);
        for i in 0..d.n() {
            s.push(d.row(i), d.y[i]);
        }
        let q = s.quad_form();
        for j in 0..4 {
            assert!((std.scale[j] - q.scale[j]).abs() < 1e-9);
            assert!((std.x_mean[j] - q.x_mean[j]).abs() < 1e-9);
        }
        assert!((std.y_mean - q.y_mean).abs() < 1e-10);
        // standardized gram agrees: (1/n) Σ xc_i xc_j == q.gram
        let nf = std.n as f64;
        for a in 0..4 {
            for b in 0..4 {
                let g: f64 = (0..std.n).map(|i| std.col(a, i) * std.col(b, i)).sum::<f64>() / nf;
                assert!(
                    (g - q.gram.get(a, b)).abs() < 1e-9,
                    "gram[{a},{b}]: {g} vs {}",
                    q.gram.get(a, b)
                );
            }
        }
    }

    #[test]
    fn columns_have_zero_mean_unit_var() {
        let d = generate(&SynthSpec::ill_conditioned(400, 3, 1e6, 5));
        let std = Standardized::from_dataset(&d);
        let nf = std.n as f64;
        for j in 0..3 {
            let mean: f64 = (0..std.n).map(|i| std.col(j, i)).sum::<f64>() / nf;
            let var: f64 = (0..std.n).map(|i| std.col(j, i).powi(2)).sum::<f64>() / nf;
            assert!(mean.abs() < 1e-9, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-6, "col {j} var {var}");
        }
    }

    #[test]
    fn degenerate_column_zeroed() {
        let d = Dataset::new(2, vec![1.0, 5.0, 2.0, 5.0, 3.0, 5.0], vec![1.0, 2.0, 3.0]);
        let std = Standardized::from_dataset(&d);
        assert_eq!(std.scale[1], 0.0);
        for i in 0..3 {
            assert_eq!(std.col(1, i), 0.0);
        }
        let (_, beta) = std.to_original_scale(&[1.0, 1.0]);
        assert_eq!(beta[1], 0.0);
    }
}
