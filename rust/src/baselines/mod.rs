//! The comparison systems the paper's claims are measured against.
//!
//! * [`serial`] — exact in-memory coordinate descent on the *raw data*
//!   (glmnet's naive residual updates).  A completely independent code path
//!   from the sufficient-statistics solver, used as the ground-truth oracle
//!   for the exactness experiment (T2): one-pass must match it to solver
//!   tolerance.
//! * [`admm`] — distributed consensus lasso/elastic-net via ADMM (Boyd et
//!   al. \[1\], §8) — the paper's "latest iterative distributed algorithms"
//!   comparator.  Every iteration is one MapReduce job; T1 charges it the
//!   modeled per-job scheduling cost.
//! * [`psgd`] — parallelized SGD with parameter averaging (Zinkevich et
//!   al. \[3\]) — the paper's "approximate algorithms" comparator for T2.
//!
//! All three standardize exactly like the one-pass path (center, unit
//! population sd, penalty on standardized coefficients) so every system
//! minimizes literally the same objective and solutions are comparable.

pub mod admm;
pub mod psgd;
pub mod serial;
pub mod standardize;

pub use admm::{admm_lasso, AdmmSettings, Admmsolution};
pub use psgd::{psgd_fit, PsgdSettings};
pub use serial::serial_cd;
pub use standardize::Standardized;
