//! Parallelized SGD with parameter averaging (Zinkevich et al. \[3\]) —
//! the paper's "approximate algorithms" comparator.
//!
//! Each of W workers runs sequential subgradient SGD over its shard of the
//! (standardized) data for one or more local passes, then the leader
//! averages the W parameter vectors.  One MapReduce job, like the one-pass
//! algorithm — but *approximate*: the averaged iterate does not satisfy the
//! lasso KKT conditions, which is exactly the gap experiment T2 measures.

use crate::data::dataset::Dataset;
use crate::model::fitted::FittedModel;
use crate::rng::Rng;
use crate::solver::penalty::Penalty;

use super::standardize::Standardized;

/// residual clip bound for SGD stability (see step loop)
const CLIP: f64 = 25.0;

/// PSGD knobs.
#[derive(Debug, Clone, Copy)]
pub struct PsgdSettings {
    pub workers: usize,
    /// local epochs over each shard
    pub epochs: usize,
    /// initial step size η₀ (decays as η₀/(1 + t/n_shard))
    pub eta0: f64,
    pub seed: u64,
}

impl Default for PsgdSettings {
    fn default() -> Self {
        PsgdSettings { workers: 8, epochs: 1, eta0: 0.02, seed: 0xFACE }
    }
}

/// Fit by one round of parallel SGD + averaging.
pub fn psgd_fit(
    data: &Dataset,
    penalty: Penalty,
    lambda: f64,
    settings: PsgdSettings,
) -> FittedModel {
    let std = Standardized::from_dataset(data);
    let (n, p) = (std.n, std.p);
    let w = settings.workers.max(1).min(n);
    let la = lambda * penalty.alpha;
    let lr = lambda * (1.0 - penalty.alpha);

    // shard bounds
    let base = n / w;
    let extra = n % w;
    let mut betas = vec![vec![0.0; p]; w];
    let mut lo = 0usize;
    for (widx, beta) in betas.iter_mut().enumerate() {
        let len = base + usize::from(widx < extra);
        let hi = lo + len;
        let mut rng = Rng::seed_from(settings.seed ^ (widx as u64) << 32);
        let mut order: Vec<usize> = (lo..hi).collect();
        let mut t = 0usize;
        for _ in 0..settings.epochs.max(1) {
            rng.shuffle(&mut order);
            for &i in &order {
                let row = &std.xc[i * p..(i + 1) * p];
                // subgradient of ½(xᵀβ − y)² + λ(a‖β‖₁ + (1−a)/2‖β‖₂²)
                let mut pred = 0.0;
                for j in 0..p {
                    pred += row[j] * beta[j];
                }
                let err = pred - std.yc[i];
                let eta = settings.eta0 / (1.0 + t as f64 / len.max(1) as f64);
                // clip the residual so a bad early step cannot blow up the
                // iterate at large p (standard SGD stabilization; keeps the
                // method approximate, not divergent)
                let err = err.clamp(-CLIP, CLIP);
                for j in 0..p {
                    let sub = la * beta[j].signum() + lr * beta[j];
                    beta[j] -= eta * (err * row[j] + sub);
                }
                t += 1;
            }
        }
        lo = hi;
    }

    // reduce: parameter averaging
    let mut avg = vec![0.0; p];
    for beta in &betas {
        for j in 0..p {
            avg[j] += beta[j];
        }
    }
    for v in avg.iter_mut() {
        *v /= w as f64;
    }
    let (alpha, beta) = std.to_original_scale(&avg);
    FittedModel { alpha, beta, lambda, penalty, n_train: n as u64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::serial::serial_cd;
    use crate::data::synth::{generate, SynthSpec};
    use crate::util::rel_l2_err;

    #[test]
    fn gets_close_but_not_exact() {
        // C2 in miniature: PSGD lands in the neighbourhood; one-pass lands
        // on the solution.
        let d = generate(&SynthSpec::sparse_linear(20_000, 6, 0.5, 3));
        let lambda = 0.05;
        let (oracle, _) = serial_cd(&d, Penalty::lasso(), lambda, 1e-12, 20_000);
        let sgd = psgd_fit(&d, Penalty::lasso(), lambda, PsgdSettings::default());
        let err = rel_l2_err(&sgd.beta, &oracle.beta);
        assert!(err < 0.3, "psgd should be in the neighbourhood, err={err}");
        assert!(err > 1e-6, "psgd must NOT be exact (it is the approximate baseline)");
    }

    #[test]
    fn no_exact_zeros_unlike_lasso() {
        // averaging destroys sparsity — a known PSGD artifact
        let d = generate(&SynthSpec::sparse_linear(10_000, 12, 0.25, 7));
        let sgd = psgd_fit(&d, Penalty::lasso(), 0.2, PsgdSettings::default());
        let exact_zeros = sgd.beta.iter().filter(|b| **b == 0.0).count();
        assert!(exact_zeros < 12 / 2, "averaged SGD rarely produces exact zeros");
    }

    #[test]
    fn deterministic_in_seed() {
        let d = generate(&SynthSpec::sparse_linear(2000, 4, 0.5, 9));
        let a = psgd_fit(&d, Penalty::lasso(), 0.1, PsgdSettings::default());
        let b = psgd_fit(&d, Penalty::lasso(), 0.1, PsgdSettings::default());
        assert_eq!(a.beta, b.beta);
        let c = psgd_fit(
            &d,
            Penalty::lasso(),
            0.1,
            PsgdSettings { seed: 1, ..Default::default() },
        );
        assert_ne!(a.beta, c.beta);
    }

    #[test]
    fn more_epochs_reduce_error() {
        let d = generate(&SynthSpec::sparse_linear(5000, 5, 0.5, 11));
        let (oracle, _) = serial_cd(&d, Penalty::lasso(), 0.05, 1e-12, 20_000);
        let one = psgd_fit(&d, Penalty::lasso(), 0.05, PsgdSettings { epochs: 1, ..Default::default() });
        let ten = psgd_fit(&d, Penalty::lasso(), 0.05, PsgdSettings { epochs: 10, ..Default::default() });
        let e1 = rel_l2_err(&one.beta, &oracle.beta);
        let e10 = rel_l2_err(&ten.beta, &oracle.beta);
        assert!(e10 < e1, "more epochs should help: {e10} vs {e1}");
    }
}
