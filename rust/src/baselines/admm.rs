//! Distributed consensus elastic-net via ADMM (Boyd et al. \[1\], §8.2) —
//! the paper's "iterative distributed algorithms" comparator.
//!
//! The data is sharded across N blocks.  Per iteration:
//!
//!   β_i ← (X_iᵀX_i/n + ρI)⁻¹ (X_iᵀy_i/n + ρ(z − u_i))      (map: per block)
//!   z   ← prox_{λP/(ρN)}(β̄ + ū)                            (reduce)
//!   u_i ← u_i + β_i − z
//!
//! On MapReduce, *every iteration is a separate job* — the map reads the
//! block (or its cached factorization) and the reduce averages.  That is
//! precisely the cost structure the one-pass paper attacks: T1 charges
//! each iteration the modeled per-job scheduling overhead and compares
//! against Algorithm 1's single job.

use crate::data::dataset::Dataset;
use crate::model::fitted::FittedModel;
use crate::solver::linalg::{chol_solve, cholesky};
use crate::solver::penalty::{soft_threshold, Penalty};

use super::standardize::Standardized;

/// ADMM knobs.
#[derive(Debug, Clone, Copy)]
pub struct AdmmSettings {
    /// augmented-Lagrangian parameter ρ
    pub rho: f64,
    /// primal/dual residual tolerance (on the standardized scale)
    pub tol: f64,
    pub max_iters: usize,
    /// number of data blocks (the simulated cluster's mappers)
    pub blocks: usize,
}

impl Default for AdmmSettings {
    fn default() -> Self {
        AdmmSettings { rho: 1.0, tol: 1e-4, max_iters: 1000, blocks: 8 }
    }
}

/// ADMM result + the cost counters T1 needs.
#[derive(Debug, Clone)]
pub struct Admmsolution {
    pub model: FittedModel,
    /// iterations executed = number of MapReduce jobs after setup
    pub iterations: usize,
    /// converged before `max_iters`?
    pub converged: bool,
    /// final primal residual ‖β_i − z‖
    pub primal_residual: f64,
    /// data passes: 1 (setup: per-block Gram + factorization); iterations
    /// afterwards reuse cached factors, so passes stay 1 — but *jobs* grow.
    pub data_passes: usize,
    pub jobs: usize,
}

/// Run consensus ADMM for one (penalty, λ).
pub fn admm_lasso(
    data: &Dataset,
    penalty: Penalty,
    lambda: f64,
    settings: AdmmSettings,
) -> Admmsolution {
    let std = Standardized::from_dataset(data);
    let (n, p) = (std.n, std.p);
    let nf = n as f64;
    let nb = settings.blocks.max(1).min(n);
    let rho = settings.rho;

    // --- setup job (1 data pass): per-block Gram, Xᵀy, Cholesky factor ---
    let bounds: Vec<(usize, usize)> = {
        let base = n / nb;
        let extra = n % nb;
        let mut lo = 0;
        (0..nb)
            .map(|i| {
                let len = base + usize::from(i < extra);
                let b = (lo, lo + len);
                lo += len;
                b
            })
            .collect()
    };
    let mut factors = Vec::with_capacity(nb);
    let mut xty = Vec::with_capacity(nb);
    for &(lo, hi) in &bounds {
        let mut gram = vec![0.0; p * p];
        let mut cvec = vec![0.0; p];
        for i in lo..hi {
            let row = &std.xc[i * p..(i + 1) * p];
            for a in 0..p {
                cvec[a] += row[a] * std.yc[i];
                for b in a..p {
                    gram[a * p + b] += row[a] * row[b];
                }
            }
        }
        for a in 0..p {
            for b in 0..a {
                gram[a * p + b] = gram[b * p + a];
            }
        }
        // scale by 1/n (global) to match the standardized objective, add ρI
        for v in gram.iter_mut() {
            *v /= nf;
        }
        for v in cvec.iter_mut() {
            *v /= nf;
        }
        for a in 0..p {
            gram[a * p + a] += rho;
        }
        factors.push(cholesky(&gram, p, 0.0).expect("gram + rho I is PD"));
        xty.push(cvec);
    }

    // --- iterate: each loop turn = one MapReduce job ---
    let la = lambda * penalty.alpha;
    let lr = lambda * (1.0 - penalty.alpha);
    let mut beta_i = vec![vec![0.0; p]; nb];
    let mut u_i = vec![vec![0.0; p]; nb];
    let mut z = vec![0.0; p];
    let mut iterations = 0;
    let mut converged = false;
    let mut primal = f64::INFINITY;
    let mut rhs = vec![0.0; p];
    while iterations < settings.max_iters {
        // map: block-local β updates
        for b in 0..nb {
            for j in 0..p {
                rhs[j] = xty[b][j] + rho * (z[j] - u_i[b][j]);
            }
            beta_i[b] = chol_solve(&factors[b], &rhs);
        }
        // reduce: averaged consensus + prox
        let mut zbar = vec![0.0; p];
        for b in 0..nb {
            for j in 0..p {
                zbar[j] += beta_i[b][j] + u_i[b][j];
            }
        }
        let z_old = z.clone();
        for j in 0..p {
            let v = zbar[j] / nb as f64;
            // prox of λ(a‖·‖₁ + (1−a)/2‖·‖₂²)/(ρN)
            z[j] = soft_threshold(v, la / (rho * nb as f64))
                / (1.0 + lr / (rho * nb as f64));
        }
        // dual updates + residuals
        let mut pr = 0.0;
        for b in 0..nb {
            for j in 0..p {
                let d = beta_i[b][j] - z[j];
                u_i[b][j] += d;
                pr += d * d;
            }
        }
        primal = (pr / nb as f64).sqrt();
        let dual: f64 = {
            let mut s = 0.0;
            for j in 0..p {
                let d = rho * (z[j] - z_old[j]);
                s += d * d;
            }
            s.sqrt()
        };
        iterations += 1;
        if primal < settings.tol && dual < settings.tol {
            converged = true;
            break;
        }
    }

    let (alpha, beta) = std.to_original_scale(&z);
    Admmsolution {
        model: FittedModel { alpha, beta, lambda, penalty, n_train: n as u64 },
        iterations,
        converged,
        primal_residual: primal,
        data_passes: 1,
        jobs: 1 + iterations, // setup job + one per iteration
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::serial::serial_cd;
    use crate::data::synth::{generate, SynthSpec};

    #[test]
    fn converges_to_the_lasso_solution() {
        let d = generate(&SynthSpec::sparse_linear(2000, 6, 0.4, 3));
        let lambda = 0.1;
        let sol = admm_lasso(
            &d,
            Penalty::lasso(),
            lambda,
            AdmmSettings { tol: 1e-7, max_iters: 5000, ..Default::default() },
        );
        assert!(sol.converged, "primal residual {}", sol.primal_residual);
        let (oracle, _) = serial_cd(&d, Penalty::lasso(), lambda, 1e-12, 20_000);
        for j in 0..6 {
            assert!(
                (sol.model.beta[j] - oracle.beta[j]).abs() < 1e-3,
                "j={j}: {} vs {}",
                sol.model.beta[j],
                oracle.beta[j]
            );
        }
    }

    #[test]
    fn needs_many_iterations_hence_many_jobs() {
        // the T1 phenomenon: tens of jobs at practical tolerance
        let d = generate(&SynthSpec::sparse_linear(4000, 16, 0.3, 7));
        let sol = admm_lasso(&d, Penalty::lasso(), 0.05, AdmmSettings::default());
        assert!(sol.converged);
        assert!(
            sol.iterations >= 10,
            "consensus ADMM should take >= 10 iterations, took {}",
            sol.iterations
        );
        assert_eq!(sol.jobs, sol.iterations + 1);
        assert_eq!(sol.data_passes, 1);
    }

    #[test]
    fn elastic_net_prox_correct() {
        let d = generate(&SynthSpec::correlated(1500, 5, 0.6, 11));
        let pen = Penalty::elastic_net(0.5);
        let sol = admm_lasso(
            &d,
            pen,
            0.2,
            AdmmSettings { tol: 1e-7, max_iters: 5000, ..Default::default() },
        );
        let (oracle, _) = serial_cd(&d, pen, 0.2, 1e-12, 20_000);
        for j in 0..5 {
            assert!((sol.model.beta[j] - oracle.beta[j]).abs() < 1e-3, "j={j}");
        }
    }

    #[test]
    fn block_count_does_not_change_fixpoint() {
        let d = generate(&SynthSpec::sparse_linear(1000, 4, 0.5, 13));
        let a = admm_lasso(
            &d,
            Penalty::lasso(),
            0.1,
            AdmmSettings { blocks: 2, tol: 1e-8, max_iters: 10_000, rho: 1.0 },
        );
        let b = admm_lasso(
            &d,
            Penalty::lasso(),
            0.1,
            AdmmSettings { blocks: 16, tol: 1e-8, max_iters: 10_000, rho: 1.0 },
        );
        for j in 0..4 {
            assert!((a.model.beta[j] - b.model.beta[j]).abs() < 1e-4);
        }
    }
}
