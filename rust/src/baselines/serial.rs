//! Serial raw-data coordinate descent — the exactness oracle.
//!
//! glmnet's "naive" (residual-update) algorithm: keep r = yc − Xcβ and
//! update one coordinate at a time with O(n) work.  It never forms XᵀX, so
//! it shares *no* numerical machinery with the sufficient-statistics path —
//! which is exactly what makes agreement between the two meaningful (T2).

use crate::data::dataset::Dataset;
use crate::model::fitted::FittedModel;
use crate::solver::penalty::{soft_threshold, Penalty};

use super::standardize::Standardized;

/// Fit by residual-update CD on raw (standardized) data; returns the model
/// in original units plus the number of sweeps used.
pub fn serial_cd(
    data: &Dataset,
    penalty: Penalty,
    lambda: f64,
    tol: f64,
    max_sweeps: usize,
) -> (FittedModel, usize) {
    let std = Standardized::from_dataset(data);
    let (n, p) = (std.n, std.p);
    let nf = n as f64;
    let la = lambda * penalty.alpha;
    let lr = lambda * (1.0 - penalty.alpha);
    let mut beta = vec![0.0; p];
    let mut r = std.yc.clone(); // residual of the standardized model
    let mut sweeps = 0;
    loop {
        let mut dmax = 0.0_f64;
        for j in 0..p {
            if std.scale[j] == 0.0 {
                continue; // degenerate column stays 0
            }
            // z = (1/n)·x_jᵀr + β_j   (columns have unit variance)
            let mut dot = 0.0;
            for i in 0..n {
                dot += std.col(j, i) * r[i];
            }
            let z = dot / nf + beta[j];
            let bj_new = soft_threshold(z, la) / (1.0 + lr);
            let delta = bj_new - beta[j];
            if delta != 0.0 {
                for i in 0..n {
                    r[i] -= std.col(j, i) * delta;
                }
                beta[j] = bj_new;
                dmax = dmax.max(delta.abs());
            }
        }
        sweeps += 1;
        if dmax < tol || sweeps >= max_sweeps {
            break;
        }
    }
    let (alpha, beta) = std.to_original_scale(&beta);
    (
        FittedModel { alpha, beta, lambda, penalty, n_train: n as u64 },
        sweeps,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::solver::cd::{solve_cd, CdSettings};
    use crate::stats::SuffStats;

    fn suffstats_fit(data: &Dataset, penalty: Penalty, lambda: f64) -> FittedModel {
        let mut s = SuffStats::new(data.p);
        for i in 0..data.n() {
            s.push(data.row(i), data.y[i]);
        }
        let q = s.quad_form();
        let sol = solve_cd(&q, penalty, lambda, None, CdSettings::default());
        let (alpha, beta) = q.to_original_scale(&sol.beta);
        FittedModel { alpha, beta, lambda, penalty, n_train: s.count() }
    }

    #[test]
    fn one_pass_matches_serial_oracle_lasso() {
        // THE exactness claim (C2) in miniature.
        let d = generate(&SynthSpec::sparse_linear(2000, 8, 0.3, 9));
        for lambda in [0.01, 0.1, 0.5] {
            let (oracle, _) = serial_cd(&d, Penalty::lasso(), lambda, 1e-12, 20_000);
            let onepass = suffstats_fit(&d, Penalty::lasso(), lambda);
            assert!((oracle.alpha - onepass.alpha).abs() < 1e-6, "lambda={lambda}");
            for j in 0..8 {
                assert!(
                    (oracle.beta[j] - onepass.beta[j]).abs() < 1e-6,
                    "lambda={lambda} j={j}: {} vs {}",
                    oracle.beta[j],
                    onepass.beta[j]
                );
            }
        }
    }

    #[test]
    fn one_pass_matches_serial_oracle_elastic_net() {
        let d = generate(&SynthSpec::correlated(1500, 6, 0.7, 13));
        let pen = Penalty::elastic_net(0.5);
        let (oracle, _) = serial_cd(&d, pen, 0.2, 1e-12, 20_000);
        let onepass = suffstats_fit(&d, pen, 0.2);
        for j in 0..6 {
            assert!((oracle.beta[j] - onepass.beta[j]).abs() < 1e-6, "j={j}");
        }
    }

    #[test]
    fn sparsity_of_serial_solution() {
        let d = generate(&SynthSpec::sparse_linear(3000, 20, 0.15, 17));
        let (m, _) = serial_cd(&d, Penalty::lasso(), 0.3, 1e-10, 10_000);
        assert!(m.nnz() < 20, "lasso at healthy lambda must be sparse");
        assert!(m.nnz() >= 2);
    }

    #[test]
    fn converges_quickly_on_orthogonal_design() {
        let d = generate(&SynthSpec::sparse_linear(500, 4, 0.5, 23));
        let (_, sweeps) = serial_cd(&d, Penalty::lasso(), 0.05, 1e-10, 1000);
        assert!(sweeps < 100, "sweeps={sweeps}");
    }
}
