//! The original-scale fitted model (paper eq. 3–4) and its serialization.

use std::fmt;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::solver::penalty::Penalty;

/// A penalized linear model in original units: ŷ = α + xᵀβ.
#[derive(Debug, Clone, PartialEq)]
pub struct FittedModel {
    pub alpha: f64,
    pub beta: Vec<f64>,
    /// λ the model was trained at (the CV-selected one in Algorithm 1)
    pub lambda: f64,
    /// penalty family (elastic-net α)
    pub penalty: Penalty,
    /// rows behind the final fit
    pub n_train: u64,
}

impl FittedModel {
    pub fn p(&self) -> usize {
        self.beta.len()
    }

    /// Number of nonzero coefficients.
    pub fn nnz(&self) -> usize {
        self.beta.iter().filter(|b| **b != 0.0).count()
    }

    /// Predict one row.
    pub fn predict(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.beta.len(), "prediction width mismatch");
        let mut acc = self.alpha;
        for j in 0..x.len() {
            acc += x[j] * self.beta[j];
        }
        acc
    }

    /// Predict a row-major batch into `out`.
    pub fn predict_batch(&self, x: &[f64], out: &mut Vec<f64>) {
        let p = self.beta.len();
        assert_eq!(x.len() % p, 0, "batch width mismatch");
        out.clear();
        for row in x.chunks_exact(p) {
            out.push(self.predict(row));
        }
    }

    /// Plain-text serialization (versioned, line-oriented).
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        s.push_str("plrmr-model v1\n");
        s.push_str(&format!("penalty_alpha {}\n", self.penalty.alpha));
        s.push_str(&format!("lambda {}\n", self.lambda));
        s.push_str(&format!("n_train {}\n", self.n_train));
        s.push_str(&format!("alpha {}\n", self.alpha));
        s.push_str(&format!("p {}\n", self.beta.len()));
        for b in &self.beta {
            s.push_str(&format!("beta {b}\n"));
        }
        s
    }

    pub fn from_text(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().context("empty model file")?;
        if header != "plrmr-model v1" {
            bail!("unsupported model header: {header:?}");
        }
        let mut penalty_alpha = None;
        let mut lambda = None;
        let mut n_train = None;
        let mut alpha = None;
        let mut p = None;
        let mut beta = Vec::new();
        for line in lines {
            let mut it = line.splitn(2, ' ');
            let key = it.next().unwrap_or("");
            let val = it.next().context("missing value")?;
            match key {
                "penalty_alpha" => penalty_alpha = Some(val.parse::<f64>()?),
                "lambda" => lambda = Some(val.parse::<f64>()?),
                "n_train" => n_train = Some(val.parse::<u64>()?),
                "alpha" => alpha = Some(val.parse::<f64>()?),
                "p" => p = Some(val.parse::<usize>()?),
                "beta" => beta.push(val.parse::<f64>()?),
                other => bail!("unknown model field {other:?}"),
            }
        }
        let p = p.context("missing p")?;
        if beta.len() != p {
            bail!("expected {p} coefficients, found {}", beta.len());
        }
        Ok(FittedModel {
            alpha: alpha.context("missing alpha")?,
            beta,
            lambda: lambda.context("missing lambda")?,
            penalty: Penalty::elastic_net(penalty_alpha.context("missing penalty_alpha")?),
            n_train: n_train.context("missing n_train")?,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_text()).with_context(|| format!("write {path:?}"))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::from_text(&text)
    }
}

impl fmt::Display for FittedModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use crate::util::table::sig;
        writeln!(
            f,
            "{} model (lambda={}, {} of {} coefficients nonzero, n={})",
            self.penalty.family(),
            sig(self.lambda, 6),
            self.nnz(),
            self.p(),
            self.n_train
        )?;
        write!(f, "  alpha = {}", sig(self.alpha, 6))?;
        for (j, b) in self.beta.iter().enumerate() {
            if *b != 0.0 {
                write!(f, "\n  beta[{j}] = {}", sig(*b, 6))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> FittedModel {
        FittedModel {
            alpha: 1.5,
            beta: vec![2.0, 0.0, -0.5],
            lambda: 0.1,
            penalty: Penalty::elastic_net(0.5),
            n_train: 1000,
        }
    }

    #[test]
    fn predict_single_and_batch() {
        let m = model();
        assert_eq!(m.predict(&[1.0, 9.0, 2.0]), 1.5 + 2.0 - 1.0);
        let mut out = Vec::new();
        m.predict_batch(&[1.0, 9.0, 2.0, 0.0, 0.0, 0.0], &mut out);
        assert_eq!(out, vec![2.5, 1.5]);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn text_round_trip() {
        let m = model();
        let back = FittedModel::from_text(&m.to_text()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn file_round_trip() {
        let m = model();
        let path = std::env::temp_dir().join(format!("plrmr-model-{}.txt", std::process::id()));
        m.save(&path).unwrap();
        let back = FittedModel::load(&path).unwrap();
        assert_eq!(m, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_malformed() {
        assert!(FittedModel::from_text("").is_err());
        assert!(FittedModel::from_text("wrong header\n").is_err());
        let truncated = "plrmr-model v1\npenalty_alpha 1\nlambda 0.1\nn_train 5\nalpha 0\np 2\nbeta 1\n";
        assert!(FittedModel::from_text(truncated).is_err());
        let unknown = "plrmr-model v1\nwat 3\n";
        assert!(FittedModel::from_text(unknown).is_err());
    }

    #[test]
    fn display_mentions_family_and_nnz() {
        let s = format!("{}", model());
        assert!(s.contains("elastic-net"));
        assert!(s.contains("2 of 3"));
        assert!(!s.contains("beta[1]"), "zero coefficients are hidden");
    }
}
