//! Model diagnostics computed from sufficient statistics alone — no data
//! pass: R², adjusted R², residual variance, and the per-coefficient
//! summary a regression report needs.

use crate::model::fitted::FittedModel;
use crate::stats::{Scatter, SuffStats};
use crate::util::table::{sig, Table};

/// Goodness-of-fit summary for (model, statistics).
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostics {
    pub n: u64,
    /// nonzero coefficients (model degrees of freedom, lasso convention)
    pub df: usize,
    pub mse: f64,
    pub rmse: f64,
    /// 1 − SSR/SST
    pub r2: f64,
    /// 1 − (1−R²)(n−1)/(n−df−1)
    pub adj_r2: f64,
    /// Var(y) — the null model's MSE
    pub y_var: f64,
}

/// Compute diagnostics of `model` against the data behind `stats` (either
/// statistic backing — reads only).
pub fn diagnostics<S: Scatter>(stats: &SuffStats<S>, model: &FittedModel) -> Diagnostics {
    assert_eq!(stats.p(), model.p(), "model/stats width mismatch");
    from_parts(
        stats.count(),
        stats.moments().weight(),
        stats.mse(model.alpha, &model.beta),
        stats.syy(),
        model.nnz(),
    )
}

/// The arithmetic behind [`diagnostics`], from scalars alone — the panel
/// store's streaming path ([`crate::store::FoldStore::diagnostics`]) feeds
/// the identical `(n, w, mse, syy)` doubles through here, so the two
/// paths produce bit-identical reports.
pub fn from_parts(n: u64, w: f64, mse: f64, syy: f64, df: usize) -> Diagnostics {
    assert!(n >= 2, "need at least 2 observations");
    let y_var = syy / w;
    let r2 = if y_var > 0.0 { 1.0 - mse / y_var } else { 0.0 };
    let nf = n as f64;
    let adj_r2 = if nf - df as f64 - 1.0 > 0.0 {
        1.0 - (1.0 - r2) * (nf - 1.0) / (nf - df as f64 - 1.0)
    } else {
        f64::NAN
    };
    Diagnostics { n, df, mse, rmse: mse.max(0.0).sqrt(), r2, adj_r2, y_var }
}

/// Render a regression report: fit summary + nonzero coefficient table
/// with standardized effect sizes (βⱼ·sdⱼ, comparable across features).
pub fn report<S: Scatter>(stats: &SuffStats<S>, model: &FittedModel) -> String {
    let d = diagnostics(stats, model);
    let w = stats.moments().weight();
    let mut t = Table::new(vec!["coef", "value", "std effect"]);
    t.row(vec![
        "(intercept)".to_string(),
        sig(model.alpha, 5),
        "-".to_string(),
    ]);
    for (j, b) in model.beta.iter().enumerate() {
        if *b != 0.0 {
            let sd = (stats.sxx(j, j) / w).sqrt();
            t.row(vec![format!("x{j}"), sig(*b, 5), sig(b * sd, 4)]);
        }
    }
    format!(
        "n = {}  df = {}  mse = {}  rmse = {}\nR² = {}  adj R² = {}\n\n{}",
        d.n,
        d.df,
        sig(d.mse, 5),
        sig(d.rmse, 5),
        sig(d.r2, 5),
        sig(d.adj_r2, 5),
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::solver::penalty::Penalty;

    fn fitted_case() -> (SuffStats, FittedModel, crate::data::Dataset) {
        let spec = SynthSpec::sparse_linear(5000, 6, 0.5, 9);
        let d = generate(&spec);
        let mut s = SuffStats::new(6);
        for i in 0..d.n() {
            s.push(d.row(i), d.y[i]);
        }
        let model = FittedModel {
            alpha: spec.intercept,
            beta: spec.true_beta(),
            lambda: 0.0,
            penalty: Penalty::lasso(),
            n_train: 5000,
        };
        (s, model, d)
    }

    #[test]
    fn r2_matches_direct_computation() {
        let (s, model, d) = fitted_case();
        let diag = diagnostics(&s, &model);
        let mse_direct = d.mse(model.alpha, &model.beta);
        assert!((diag.mse - mse_direct).abs() < 1e-9);
        // noise 1.0 on strong signal: R² high but < 1
        assert!(diag.r2 > 0.5 && diag.r2 < 1.0, "r2={}", diag.r2);
        assert!(diag.adj_r2 <= diag.r2);
        assert_eq!(diag.df, model.nnz());
        assert!((diag.rmse * diag.rmse - diag.mse).abs() < 1e-12);
    }

    #[test]
    fn null_model_has_zero_r2() {
        let (s, _, _) = fitted_case();
        let null = FittedModel {
            alpha: s.y_mean(),
            beta: vec![0.0; 6],
            lambda: 1.0,
            penalty: Penalty::lasso(),
            n_train: s.count(),
        };
        let diag = diagnostics(&s, &null);
        assert!(diag.r2.abs() < 1e-9, "r2={}", diag.r2);
        assert_eq!(diag.df, 0);
    }

    #[test]
    fn report_renders_nonzero_rows_only() {
        let (s, model, _) = fitted_case();
        let r = report(&s, &model);
        assert!(r.contains("(intercept)"));
        assert!(r.contains("R²"));
        let rows = r.lines().filter(|l| l.starts_with("| x")).count();
        assert_eq!(rows, model.nnz());
    }
}
