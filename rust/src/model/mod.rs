//! Fitted-model types and reporting.
//!
//! * [`fitted`] — the original-scale model (α, β) with prediction, metadata
//!   and a plain-text serialization (no serde in the offline vendor set).
//! * [`report`] — human-readable CV reports (the `pre(λ)` table / F3 curve).

//! * [`mod@diagnostics`] — R²/adjusted-R²/effect sizes from statistics alone.

pub mod diagnostics;
pub mod fitted;
pub mod report;

pub use diagnostics::{diagnostics, Diagnostics};
pub use fitted::FittedModel;
pub use report::cv_report;
