//! CV reporting: render the `pre(λ)` curve (Algorithm 1's optional return
//! value and our experiment F3) as a table plus an ASCII sparkline.

use crate::cv::CvResult;
use crate::util::table::{sig, Table};

/// Render the CV curve as a markdown table with the selected λs marked.
pub fn cv_report(cv: &CvResult) -> String {
    let mut t = Table::new(vec!["lambda", "cv mse", "se", "nnz", ""]);
    for (i, &lam) in cv.lambdas.iter().enumerate() {
        let mark = if i == cv.opt_index {
            "<- lambda_opt"
        } else if cv.lambdas[i] == cv.lambda_1se && cv.lambda_1se != cv.lambda_opt {
            "<- 1-SE"
        } else {
            ""
        };
        t.row(vec![
            sig(lam, 4),
            sig(cv.mean_err[i], 5),
            sig(cv.se_err[i], 3),
            format!("{:.1}", cv.mean_nnz[i]),
            mark.to_string(),
        ]);
    }
    format!(
        "{}\n\nlambda_opt = {}  (cv mse {})\nlambda_1se = {}\n{}",
        t.render(),
        sig(cv.lambda_opt, 6),
        sig(cv.mean_err[cv.opt_index], 6),
        sig(cv.lambda_1se, 6),
        sparkline(&cv.mean_err)
    )
}

/// A one-line ASCII sparkline of the CV curve (log-ish scaled).
pub fn sparkline(values: &[f64]) -> String {
    const LEVELS: &[char] = &['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if values.is_empty() {
        return String::new();
    }
    let lo = values.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-300);
    let mut s = String::from("cv curve: ");
    for &v in values {
        let t = ((v - lo) / span * (LEVELS.len() - 1) as f64).round() as usize;
        s.push(LEVELS[t.min(LEVELS.len() - 1)]);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_cv() -> CvResult {
        CvResult {
            lambdas: vec![1.0, 0.5, 0.25, 0.125],
            mean_err: vec![4.0, 2.0, 1.5, 1.8],
            se_err: vec![0.4, 0.2, 0.15, 0.2],
            fold_err: vec![vec![4.0; 3], vec![2.0; 3], vec![1.5; 3], vec![1.8; 3]],
            mean_nnz: vec![0.0, 2.0, 3.0, 4.0],
            lambda_opt: 0.25,
            lambda_1se: 0.5,
            opt_index: 2,
        }
    }

    #[test]
    fn report_marks_selection() {
        let r = cv_report(&fake_cv());
        assert!(r.contains("<- lambda_opt"));
        assert!(r.contains("<- 1-SE"));
        assert!(r.contains("lambda_opt = 0.25"));
        assert!(r.contains("cv curve:"));
    }

    #[test]
    fn sparkline_extremes() {
        let s = sparkline(&[0.0, 1.0]);
        assert!(s.ends_with("▁█"));
        assert_eq!(sparkline(&[]), "");
        // constant input must not panic (zero span)
        let c = sparkline(&[3.0, 3.0, 3.0]);
        assert_eq!(c.chars().filter(|c| *c == '▁').count(), 3);
    }
}
