//! cargo-bench entry for experiment t3 — regenerates the corresponding
//! EXPERIMENTS.md table/figure (T3: CV at no extra data passes (paper claim C3)).
//! Pass --quick (after --) to shrink the workload ~10x.

use plrmr::experiments::{self, ExpOptions};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExpOptions { quick, workers: 0 };
    match experiments::run("t3", opts) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("t3_cv_passes failed: {e:#}");
            std::process::exit(1);
        }
    }
}
