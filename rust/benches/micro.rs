//! Micro-benchmarks of the hot paths (§Perf of EXPERIMENTS.md):
//!   * `Moments::push` — the mapper inner loop (O(p²)/row)
//!   * `Moments::merge` / `Moments::sub` — combiner/CV algebra (O(p²))
//!   * `solve_cd` cold and warm — the per-(fold, λ) solver
//!   * full CV sweep — the driver-side phase
//!   * HLO chunk_stats block + cd_sweep call — the PJRT path (if artifacts
//!     are built)
//!
//! Run: `cargo bench --offline` (all benches) or `cargo bench --bench micro`.

use plrmr::bench::{bench, render, render_throughput, BenchConfig};
use plrmr::cv::{cross_validate, FoldStats};
use plrmr::data::synth::{generate, SynthSpec};
use plrmr::rng::Rng;
use plrmr::solver::path::lambda_grid;
use plrmr::solver::{solve_cd, CdSettings, Penalty};
use plrmr::stats::{Moments, SuffStats};

fn main() {
    let cfg = BenchConfig::default();
    let mut rows_results = Vec::new();
    let mut op_results = Vec::new();

    // --- Moments push at several widths (the map hot loop):
    //     scalar rank-1 per row vs the blocked centered-gram path (§Perf)
    for p in [8usize, 32, 128] {
        let d = p + 1;
        let mut rng = Rng::seed_from(1);
        let block: Vec<f64> = (0..4096 * d).map(|_| rng.normal()).collect();
        let scalar = bench(&format!("moments_push scalar p={p} (4096 rows)"), cfg, || {
            let mut m = Moments::new(d);
            for row in block.chunks_exact(d) {
                m.push(row);
            }
            m.count()
        });
        rows_results.push((scalar, 4096.0, "rows"));
        let blocked = bench(&format!("moments_push blocked p={p} (4096 rows)"), cfg, || {
            let mut m = Moments::new(d);
            m.push_block(&block);
            m.count()
        });
        rows_results.push((blocked, 4096.0, "rows"));
    }

    // --- merge / sub at p=64 ---
    {
        let p = 64;
        let data = generate(&SynthSpec::sparse_linear(4000, p, 0.3, 3));
        let mut a = SuffStats::new(p);
        let mut b = SuffStats::new(p);
        for i in 0..2000 {
            a.push(data.row(i), data.y[i]);
            b.push(data.row(i + 2000), data.y[i + 2000]);
        }
        op_results.push(bench("suffstats_merge p=64", cfg, || {
            let mut acc = a.clone();
            acc.merge(&b);
            acc.count()
        }));
        let mut total = a.clone();
        total.merge(&b);
        op_results.push(bench("suffstats_sub p=64", cfg, || total.sub(&a).count()));
        op_results.push(bench("quad_form p=64", cfg, || total.quad_form().p));
    }

    // --- engine shuffle/reduce: the fixed merge tree over task outputs ---
    {
        use plrmr::mapreduce::{run_job, Emitter, EngineConfig, TaskCtx};
        let p = 64;
        let k = 10;
        let n_tasks = 64usize;
        let inputs: Vec<usize> = (0..n_tasks).collect();
        let run = |combine: bool| {
            let mut ecfg = EngineConfig::with_workers(8);
            ecfg.combine = combine;
            let map = |ctx: &TaskCtx, _t: &usize, em: &mut Emitter<usize, SuffStats>| {
                // tiny per-task stats so tree-merge cost dominates the job
                let mut rng = Rng::seed_from(ctx.task_id as u64 + 1);
                for fold in 0..k {
                    let mut s = SuffStats::new(p);
                    for _ in 0..2 {
                        let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
                        let y = rng.normal();
                        s.push(&x, y);
                    }
                    em.emit(fold, s);
                }
            };
            run_job(&ecfg, &inputs, map).unwrap()
        };
        op_results.push(bench(
            &format!("engine tree-reduce w=8 ({n_tasks} tasks, k={k}, p={p})"),
            cfg,
            || run(false).metrics.reduce_s,
        ));
        op_results.push(bench(
            &format!("engine tree-reduce + worker combine w=8 ({n_tasks} tasks)"),
            cfg,
            || run(true).metrics.reduce_s,
        ));
    }

    // --- CD solve cold/warm, CV sweep ---
    {
        let p = 64;
        let data = generate(&SynthSpec::sparse_linear(20_000, p, 0.2, 5));
        let mut s = SuffStats::new(p);
        for i in 0..data.n() {
            s.push(data.row(i), data.y[i]);
        }
        let q = s.quad_form();
        let lam = q.lambda_max(1.0) * 0.05;
        op_results.push(bench("solve_cd cold p=64", cfg, || {
            solve_cd(&q, Penalty::lasso(), lam, None, CdSettings::default()).sweeps
        }));
        let near = solve_cd(&q, Penalty::lasso(), lam * 1.2, None, CdSettings::default());
        op_results.push(bench("solve_cd warm p=64", cfg, || {
            solve_cd(&q, Penalty::lasso(), lam, Some(&near.beta), CdSettings::default()).sweeps
        }));

        // full CV phase (k=5, 30 lambdas) from fold statistics
        let mut folds: Vec<SuffStats> = (0..5).map(|_| SuffStats::new(p)).collect();
        for i in 0..data.n() {
            folds[i % 5].push(data.row(i), data.y[i]);
        }
        let fs = FoldStats::new(folds).unwrap();
        let grid = lambda_grid(fs.total().quad_form().lambda_max(1.0), 30, 1e-3);
        op_results.push(bench("cv_phase k=5 x 30 lambdas p=64", cfg, || {
            cross_validate(&fs, Penalty::lasso(), &grid, CdSettings::default())
                .unwrap()
                .lambda_opt
        }));
    }

    // --- PJRT paths (when artifacts exist AND the pjrt feature is on;
    //     without the feature the runtime types are inert stubs) ---
    let dir = plrmr::runtime::default_artifacts_dir();
    if cfg!(feature = "pjrt") && dir.join("manifest.json").exists() {
        use plrmr::runtime::{Catalog, HloCdSolver, HloStatsMapper};
        let catalog = Catalog::load(&dir).unwrap();
        let p = 32;
        let data = generate(&SynthSpec::sparse_linear(8192, p, 0.3, 7));
        let mut mapper = HloStatsMapper::new(&catalog, p).unwrap();
        let bn = mapper.block_n;
        let stats = bench(&format!("hlo_chunk_stats p={p} block={bn}"), cfg, || {
            let mut acc = SuffStats::new(p);
            mapper
                .fold_rows(&data.x[..bn * p], &data.y[..bn], &mut acc)
                .unwrap();
            acc.count()
        });
        rows_results.push((stats, bn as f64, "rows"));

        let mut s = SuffStats::new(p);
        for i in 0..data.n() {
            s.push(data.row(i), data.y[i]);
        }
        let q = s.quad_form();
        let mut cd = HloCdSolver::new(&catalog, p).unwrap();
        op_results.push(bench("hlo_cd_solve p=32", cfg, || {
            cd.solve(&q, 0.05, 1.0, 1e-6, 200).unwrap().len()
        }));
    } else {
        eprintln!("(artifacts not built or pjrt feature off — skipping PJRT micro-benches)");
    }

    println!("## micro-benchmarks (hot paths)\n");
    println!("{}\n", render_throughput(&rows_results));
    println!("{}", render(&op_results));
}
