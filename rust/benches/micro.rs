//! Micro-benchmarks of the hot paths (§Perf of EXPERIMENTS.md):
//!   * `Moments::push` — the mapper inner loop (O(p²)/row)
//!   * sparse ingest — nonzero-aware scatter vs the dense kernels, at the
//!     raw rank-1 level (row density) and the `push_block_sparse` map
//!     path (chunk-level support union), bit-identity asserted inline
//!   * `Moments::merge` / `Moments::sub` — combiner/CV algebra (O(p²))
//!   * `solve_cd` cold and warm — the per-(fold, λ) solver
//!   * full CV sweep — the driver-side phase
//!   * HLO chunk_stats block + cd_sweep call — the PJRT path (if artifacts
//!     are built)
//!
//! Run: `cargo bench --offline` (all benches) or
//! `cargo bench --bench micro [-- --quick]`.

use plrmr::bench::{bench, render, render_throughput, BenchConfig};
use plrmr::cv::{cross_validate, FoldStats};
use plrmr::data::synth::{generate, SynthSpec};
use plrmr::rng::Rng;
use plrmr::solver::path::lambda_grid;
use plrmr::solver::{solve_cd, CdSettings, Penalty};
use plrmr::stats::symm::SymMat;
use plrmr::stats::{Moments, SuffStats};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let mut rows_results = Vec::new();
    let mut op_results = Vec::new();

    // --- Moments push at several widths (the map hot loop):
    //     scalar rank-1 per row vs the blocked centered-gram path (§Perf)
    for p in [8usize, 32, 128] {
        let d = p + 1;
        let mut rng = Rng::seed_from(1);
        let block: Vec<f64> = (0..4096 * d).map(|_| rng.normal()).collect();
        let scalar = bench(&format!("moments_push scalar p={p} (4096 rows)"), cfg, || {
            let mut m = Moments::new(d);
            for row in block.chunks_exact(d) {
                m.push(row);
            }
            m.count()
        });
        rows_results.push((scalar, 4096.0, "rows"));
        let blocked = bench(&format!("moments_push blocked p={p} (4096 rows)"), cfg, || {
            let mut m = Moments::new(d);
            m.push_block(&block);
            m.count()
        });
        rows_results.push((blocked, 4096.0, "rows"));
    }

    // --- sparse ingest: nonzero-aware scatter vs dense (§Perf) ----------
    // Two granularities on the same masked blocks:
    //   * raw rank-1 scatter at *row* density — the kernel bound (only
    //     idx × idx triangle pairs are touched);
    //   * `Moments::push_block_sparse` — the map path, where centering
    //     densifies every touched column, so the chunk-level support
    //     union governs the win.
    // The sparse paths are asserted bit-identical to the dense ones
    // inline — that is the contract (±0.0-skip), not a bench outcome.
    {
        let ps: &[usize] = if quick { &[128, 256] } else { &[1024, 4096] };
        let rows = 48; // one cache chunk at every d here, ≥ BLOCK_MIN_ROWS
        let srows = 16; // raw-scatter rows (dense rank1 is O(d²) each)
        for &p in ps {
            let d = p + 1;
            for density in [0.01f64, 0.1, 1.0] {
                let mut rng = Rng::seed_from(90 + p as u64);
                let mut block: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
                if density < 1.0 {
                    for v in block.iter_mut() {
                        if !rng.coin(density) {
                            *v = 0.0;
                        }
                    }
                }

                // contract: the sparse map path is bit-identical to dense
                let mut dm = Moments::new(d);
                dm.push_block(&block);
                let mut sm = Moments::new(d);
                sm.push_block_sparse(&block);
                assert_eq!(dm.count(), sm.count());
                let same_bits = |a: &[f64], b: &[f64]| {
                    a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
                };
                assert!(
                    same_bits(dm.mean(), sm.mean())
                        && same_bits(dm.m2_packed().as_slice(), sm.m2_packed().as_slice()),
                    "sparse ingest drifted from dense (p={p}, density={density})"
                );

                let tag = format!("p={p} nz={density}");
                let dense = bench(&format!("moments_push dense {tag} ({rows} rows)"), cfg, || {
                    let mut m = Moments::new(d);
                    m.push_block(&block);
                    m.count()
                });
                rows_results.push((dense, rows as f64, "rows"));
                let sparse =
                    bench(&format!("moments_push sparse {tag} ({rows} rows)"), cfg, || {
                        let mut m = Moments::new(d);
                        m.push_block_sparse(&block);
                        m.count()
                    });
                rows_results.push((sparse, rows as f64, "rows"));

                // raw scatter kernel at row density; the verification pass
                // below doubles as the bit-identity check
                let idx: Vec<Vec<usize>> = block
                    .chunks_exact(d)
                    .take(srows)
                    .map(|r| (0..d).filter(|&j| r[j] != 0.0).collect())
                    .collect();
                let mut acc = SymMat::zeros(d);
                let mut sacc = SymMat::zeros(d);
                for (r, ix) in block.chunks_exact(d).take(srows).zip(&idx) {
                    acc.rank1(r, 1.0);
                    sacc.rank1_sparse(ix, r, 1.0);
                }
                assert!(
                    same_bits(acc.as_slice(), sacc.as_slice()),
                    "sparse scatter drifted from dense (p={p}, density={density})"
                );
                let dscat =
                    bench(&format!("scatter rank1 dense {tag} ({srows} rows)"), cfg, || {
                        for r in block.chunks_exact(d).take(srows) {
                            acc.rank1(r, 1.0);
                        }
                        acc.as_slice()[0]
                    });
                rows_results.push((dscat, srows as f64, "rows"));
                let sscat =
                    bench(&format!("scatter rank1_sparse {tag} ({srows} rows)"), cfg, || {
                        for (r, ix) in block.chunks_exact(d).take(srows).zip(&idx) {
                            sacc.rank1_sparse(ix, r, 1.0);
                        }
                        sacc.as_slice()[0]
                    });
                rows_results.push((sscat, srows as f64, "rows"));
            }
        }
    }

    // --- SIMD scatter microkernel vs the scalar oracle (§Perf) ----------
    // Both paths run the identical mul-then-add expression per element
    // (no FMA, no reassociation), so they are bit-identical by
    // construction — asserted inline on every shape before timing.  The
    // headline row is rank-4 dense at the largest p: the mapper's blocked
    // centered-gram flush spends its time there.
    {
        use plrmr::stats::simd::{self, KernelMode};
        let ps: &[usize] = if quick { &[128, 256] } else { &[1024, 4096] };
        if !simd::simd_available() {
            eprintln!("(no AVX2 on this host — forced-simd rows fall back to scalar)");
        }
        let same_bits =
            |a: &[f64], b: &[f64]| a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
        for &p in ps {
            let d = p + 1;
            let mut rng = Rng::seed_from(140 + p as u64);
            let c: Vec<Vec<f64>> =
                (0..4).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
            // 10%-support sparse index set, sorted ascending as the
            // kernels require
            let idx: Vec<usize> = (0..d).filter(|_| rng.coin(0.1)).collect();

            // contract first: a forced-scalar and a forced-simd pass over
            // every kernel shape must agree bit-for-bit
            let run_all = |mode: KernelMode| {
                simd::set_kernel_override(mode);
                let mut acc = SymMat::zeros(d);
                acc.rank1(&c[0], 1.0);
                acc.rank4(&c[0], &c[1], &c[2], &c[3]);
                acc.rank1_sparse(&idx, &c[1], 1.0);
                acc.rank4_sparse(&idx, &c[0], &c[1], &c[2], &c[3]);
                simd::set_kernel_override(KernelMode::Auto);
                acc
            };
            let oracle = run_all(KernelMode::Scalar);
            let vector = run_all(KernelMode::Simd);
            assert!(
                same_bits(oracle.as_slice(), vector.as_slice()),
                "SIMD kernels drifted from the scalar oracle (p={p})"
            );

            let mut rank4_means = Vec::new();
            for (mode, name) in [(KernelMode::Scalar, "scalar"), (KernelMode::Simd, "simd")] {
                simd::set_kernel_override(mode);
                let mut acc = SymMat::zeros(d);
                let r4 = bench(&format!("scatter rank4 dense {name} p={p}"), cfg, || {
                    acc.rank4(&c[0], &c[1], &c[2], &c[3]);
                    acc.as_slice()[0]
                });
                rank4_means.push(r4.mean_s);
                op_results.push(r4);
                let mut acc = SymMat::zeros(d);
                op_results.push(bench(&format!("scatter rank1 dense {name} p={p}"), cfg, || {
                    acc.rank1(&c[0], 1.0);
                    acc.as_slice()[0]
                }));
                let mut acc = SymMat::zeros(d);
                op_results.push(bench(
                    &format!("scatter rank4_sparse {name} p={p} nz=0.1"),
                    cfg,
                    || {
                        acc.rank4_sparse(&idx, &c[0], &c[1], &c[2], &c[3]);
                        acc.as_slice()[0]
                    },
                ));
                simd::set_kernel_override(KernelMode::Auto);
            }
            if simd::simd_available() && rank4_means[1] > 0.0 {
                println!(
                    "scatter rank4 dense p={p}: simd is {}x scalar",
                    plrmr::util::table::sig(rank4_means[0] / rank4_means[1], 3)
                );
            }
        }
    }

    // --- merge / sub at p=64 ---
    {
        let p = 64;
        let data = generate(&SynthSpec::sparse_linear(4000, p, 0.3, 3));
        let mut a = SuffStats::new(p);
        let mut b = SuffStats::new(p);
        for i in 0..2000 {
            a.push(data.row(i), data.y[i]);
            b.push(data.row(i + 2000), data.y[i + 2000]);
        }
        op_results.push(bench("suffstats_merge p=64", cfg, || {
            let mut acc = a.clone();
            acc.merge(&b);
            acc.count()
        }));
        let mut total = a.clone();
        total.merge(&b);
        op_results.push(bench("suffstats_sub p=64", cfg, || total.sub(&a).count()));
        op_results.push(bench("quad_form p=64", cfg, || total.quad_form().p));
    }

    // --- engine shuffle/reduce: the fixed merge tree over task outputs ---
    {
        use plrmr::mapreduce::{run_job, Emitter, EngineConfig, TaskCtx};
        let p = 64;
        let k = 10;
        let n_tasks = 64usize;
        let inputs: Vec<usize> = (0..n_tasks).collect();
        let run = |combine: bool| {
            let mut ecfg = EngineConfig::with_workers(8);
            ecfg.combine = combine;
            let map = |ctx: &TaskCtx, _t: &usize, em: &mut Emitter<usize, SuffStats>| {
                // tiny per-task stats so tree-merge cost dominates the job
                let mut rng = Rng::seed_from(ctx.task_id as u64 + 1);
                for fold in 0..k {
                    let mut s = SuffStats::new(p);
                    for _ in 0..2 {
                        let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
                        let y = rng.normal();
                        s.push(&x, y);
                    }
                    em.emit(fold, s);
                }
            };
            run_job(&ecfg, &inputs, map).unwrap()
        };
        op_results.push(bench(
            &format!("engine tree-reduce w=8 ({n_tasks} tasks, k={k}, p={p})"),
            cfg,
            || run(false).metrics.reduce_s,
        ));
        op_results.push(bench(
            &format!("engine tree-reduce + worker combine w=8 ({n_tasks} tasks)"),
            cfg,
            || run(true).metrics.reduce_s,
        ));
    }

    // --- CD solve cold/warm, CV sweep ---
    {
        let p = 64;
        let data = generate(&SynthSpec::sparse_linear(20_000, p, 0.2, 5));
        let mut s = SuffStats::new(p);
        for i in 0..data.n() {
            s.push(data.row(i), data.y[i]);
        }
        let q = s.quad_form();
        let lam = q.lambda_max(1.0) * 0.05;
        op_results.push(bench("solve_cd cold p=64", cfg, || {
            solve_cd(&q, Penalty::lasso(), lam, None, CdSettings::default()).sweeps
        }));
        let near = solve_cd(&q, Penalty::lasso(), lam * 1.2, None, CdSettings::default());
        op_results.push(bench("solve_cd warm p=64", cfg, || {
            solve_cd(&q, Penalty::lasso(), lam, Some(&near.beta), CdSettings::default()).sweeps
        }));

        // full CV phase (k=5, 30 lambdas) from fold statistics
        let mut folds: Vec<SuffStats> = (0..5).map(|_| SuffStats::new(p)).collect();
        for i in 0..data.n() {
            folds[i % 5].push(data.row(i), data.y[i]);
        }
        let fs = FoldStats::new(folds).unwrap();
        let grid = lambda_grid(fs.total().quad_form().lambda_max(1.0), 30, 1e-3);
        op_results.push(bench("cv_phase k=5 x 30 lambdas p=64", cfg, || {
            cross_validate(&fs, Penalty::lasso(), &grid, CdSettings::default())
                .unwrap()
                .lambda_opt
        }));
    }

    // --- PJRT paths (when artifacts exist AND the pjrt feature is on;
    //     without the feature the runtime types are inert stubs) ---
    let dir = plrmr::runtime::default_artifacts_dir();
    if cfg!(feature = "pjrt") && dir.join("manifest.json").exists() {
        use plrmr::runtime::{Catalog, HloCdSolver, HloStatsMapper};
        let catalog = Catalog::load(&dir).unwrap();
        let p = 32;
        let data = generate(&SynthSpec::sparse_linear(8192, p, 0.3, 7));
        let mut mapper = HloStatsMapper::new(&catalog, p).unwrap();
        let bn = mapper.block_n;
        let stats = bench(&format!("hlo_chunk_stats p={p} block={bn}"), cfg, || {
            let mut acc = SuffStats::new(p);
            mapper
                .fold_rows(&data.x[..bn * p], &data.y[..bn], &mut acc)
                .unwrap();
            acc.count()
        });
        rows_results.push((stats, bn as f64, "rows"));

        let mut s = SuffStats::new(p);
        for i in 0..data.n() {
            s.push(data.row(i), data.y[i]);
        }
        let q = s.quad_form();
        let mut cd = HloCdSolver::new(&catalog, p).unwrap();
        op_results.push(bench("hlo_cd_solve p=32", cfg, || {
            cd.solve(&q, 0.05, 1.0, 1e-6, 200).unwrap().len()
        }));
    } else {
        eprintln!("(artifacts not built or pjrt feature off — skipping PJRT micro-benches)");
    }

    println!("## micro-benchmarks (hot paths)\n");
    println!("{}\n", render_throughput(&rows_results));
    println!("{}", render(&op_results));
}
