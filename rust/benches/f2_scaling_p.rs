//! cargo-bench entry for experiment f2 — regenerates the corresponding
//! EXPERIMENTS.md table/figure (F2: scaling in p (paper claim C5)).
//! Pass --quick (after --) to shrink the workload ~10x.

use plrmr::experiments::{self, ExpOptions};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExpOptions { quick, workers: 0 };
    match experiments::run("f2", opts) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("f2_scaling_p failed: {e:#}");
            std::process::exit(1);
        }
    }
}
