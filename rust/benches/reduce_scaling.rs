//! Reduce-phase scaling of the engine's parallel tree reduce (§Perf of
//! EXPERIMENTS.md).
//!
//! Workload shape: `n_tasks` map tasks each emitting k per-fold SuffStats
//! at large p, so the merge work is O(n_tasks · k · p²) — the regime where
//! the old leader-serial fold-in dominated wall-clock.  Two measurements:
//!
//! * **tree scaling** (worker combining OFF): the full `n_tasks − 1`
//!   merges execute in the reduce phase, level-parallel across workers.
//!   `reduce_s` should fall ≥2× from 1 → 8 workers on multicore hardware.
//! * **combining ON**: adjacent task runs pre-merge on the workers during
//!   the map phase, so leader payloads collapse toward O(workers) and the
//!   residual reduce phase nearly vanishes.
//!
//! Run: `cargo bench --bench reduce_scaling [-- --quick]`

use plrmr::bench::render_job_phases;
use plrmr::mapreduce::{run_job, Emitter, EngineConfig, JobMetrics, TaskCtx};
use plrmr::rng::Rng;
use plrmr::stats::SuffStats;
use plrmr::util::table::sig;

/// One job: every task emits k fold-keyed SuffStats derived purely from
/// its task id (the engine's purity contract).
fn job(workers: usize, combine: bool, n_tasks: usize, k: usize, p: usize) -> JobMetrics {
    let inputs: Vec<usize> = (0..n_tasks).collect();
    let mut cfg = EngineConfig::with_workers(workers);
    cfg.combine = combine;
    let out = run_job(
        &cfg,
        &inputs,
        |ctx: &TaskCtx, _t: &usize, em: &mut Emitter<usize, SuffStats>| {
            let mut rng = Rng::seed_from(0xACE0 + ctx.task_id as u64);
            for fold in 0..k {
                let mut s = SuffStats::new(p);
                for _ in 0..4 {
                    let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
                    let y = rng.normal();
                    s.push(&x, y);
                }
                em.emit(fold, s);
            }
        },
    )
    .unwrap();
    assert_eq!(out.output.len(), k);
    out.metrics
}

/// Best-of-N metrics by reduce time (min is the stable statistic here).
fn best_reduce(
    reps: usize,
    workers: usize,
    combine: bool,
    n_tasks: usize,
    k: usize,
    p: usize,
) -> JobMetrics {
    let mut best: Option<JobMetrics> = None;
    for _ in 0..reps {
        let m = job(workers, combine, n_tasks, k, p);
        let better = match &best {
            Some(b) => m.reduce_s < b.reduce_s,
            None => true,
        };
        if better {
            best = Some(m);
        }
    }
    best.unwrap()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (n_tasks, k, p, reps) = if quick { (64, 10, 200, 3) } else { (128, 10, 256, 5) };
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);

    println!(
        "## reduce_scaling — parallel tree reduce (n_tasks={n_tasks}, k={k}, p={p}; {cores} core(s))\n"
    );

    // warm up allocators/threads once
    let _ = job(2, false, n_tasks, k, p);

    let mut rows: Vec<(String, JobMetrics)> = Vec::new();
    let mut base_reduce = 0.0;
    let mut reduce_at: Vec<(usize, f64)> = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let m = best_reduce(reps, workers, false, n_tasks, k, p);
        if workers == 1 {
            base_reduce = m.reduce_s;
        }
        reduce_at.push((workers, m.reduce_s));
        rows.push((format!("tree only, w={workers}"), m));
    }
    // worker combining on, widest pool: payloads collapse toward O(workers)
    let combined = best_reduce(reps, 8, true, n_tasks, k, p);
    rows.push(("combine on, w=8".to_string(), combined));

    println!("{}\n", render_job_phases(&rows));

    for (workers, reduce_s) in &reduce_at {
        if *workers > 1 && *reduce_s > 0.0 {
            println!(
                "reduce speedup w={workers}: {}x",
                sig(base_reduce / reduce_s, 3)
            );
        }
    }
    println!(
        "\ntree shape is fixed by n_tasks, so every row above produced the\n\
         bit-identical output map (determinism is asserted in the engine tests);\n\
         only WHERE the merges ran changed."
    );
    if cores < 4 {
        println!("(NOTE: {cores}-core container — wallclock scaling is capped by hardware.)");
    }
}
