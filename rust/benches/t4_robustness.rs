//! cargo-bench entry for experiment t4 — regenerates the corresponding
//! EXPERIMENTS.md table/figure (T4: numerical robustness (paper claim C4)).
//! Pass --quick (after --) to shrink the workload ~10x.

use plrmr::experiments::{self, ExpOptions};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExpOptions { quick, workers: 0 };
    match experiments::run("t4", opts) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("t4_robustness failed: {e:#}");
            std::process::exit(1);
        }
    }
}
