//! cargo-bench entry for experiment t1 — regenerates the corresponding
//! EXPERIMENTS.md table/figure (T1: one-pass vs iterative ADMM (paper claim C1)).
//! Pass --quick (after --) to shrink the workload ~10x.

use plrmr::experiments::{self, ExpOptions};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExpOptions { quick, workers: 0 };
    match experiments::run("t1", opts) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("t1_onepass_vs_admm failed: {e:#}");
            std::process::exit(1);
        }
    }
}
