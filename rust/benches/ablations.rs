//! Ablation benches for the design choices DESIGN.md calls out:
//!   A1 active-set iteration on/off (solver)
//!   A2 warm-started path vs cold fits (solver/path)
//!   A3 split size (engine task granularity)
//!   A4 serial vs parallel CV phase (the paper's §4 extension)
//!
//! Run: `cargo bench --bench ablations [-- --quick]`

use plrmr::bench::{bench, BenchConfig};
use plrmr::config::FitConfig;
use plrmr::coordinator::Driver;
use plrmr::cv::{cross_validate, cross_validate_parallel, FoldStats};
use plrmr::data::synth::{generate, SynthSpec};
use plrmr::mapreduce::EngineConfig;
use plrmr::solver::path::{fit_path, lambda_grid};
use plrmr::solver::{solve_cd, CdSettings, Penalty};
use plrmr::stats::SuffStats;
use plrmr::util::table::{sig, Table};
use plrmr::util::timer::fmt_secs;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let n = if quick { 20_000 } else { 100_000 };
    let p = 64;

    let data = generate(&SynthSpec::sparse_linear(n, p, 0.15, 11));
    let mut s = SuffStats::new(p);
    for i in 0..data.n() {
        s.push(data.row(i), data.y[i]);
    }
    let q = s.quad_form();
    let grid = lambda_grid(q.lambda_max(1.0), 50, 1e-3);

    let mut t = Table::new(vec!["ablation", "variant", "time", "ratio"]);

    // A1: active set
    let lam = q.lambda_max(1.0) * 0.02;
    let on = bench("cd active-set on", cfg, || {
        solve_cd(&q, Penalty::lasso(), lam, None, CdSettings::default()).sweeps
    });
    let off = bench("cd active-set off", cfg, || {
        solve_cd(
            &q,
            Penalty::lasso(),
            lam,
            None,
            CdSettings { active_set: false, ..Default::default() },
        )
        .sweeps
    });
    t.row(vec!["A1 active set".into(), "on".into(), fmt_secs(on.mean_s), "1.00".into()]);
    t.row(vec![
        "A1 active set".into(),
        "off".into(),
        fmt_secs(off.mean_s),
        sig(off.mean_s / on.mean_s, 3),
    ]);

    // A2: warm path vs cold fits
    let warm = bench("path warm", cfg, || {
        fit_path(&q, Penalty::lasso(), &grid, CdSettings::default()).len()
    });
    let cold = bench("path cold", cfg, || {
        grid.iter()
            .map(|&l| solve_cd(&q, Penalty::lasso(), l, None, CdSettings::default()).sweeps)
            .sum::<usize>()
    });
    t.row(vec!["A2 lambda path".into(), "warm starts".into(), fmt_secs(warm.mean_s), "1.00".into()]);
    t.row(vec![
        "A2 lambda path".into(),
        "cold fits".into(),
        fmt_secs(cold.mean_s),
        sig(cold.mean_s / warm.mean_s, 3),
    ]);

    // A3: split size (task granularity through the whole map phase)
    let mut base = f64::NAN;
    for (label, split) in [("4k rows", 4096usize), ("64k rows", 65_536), ("1 giant split", usize::MAX)] {
        let split_rows = split.min(data.n());
        let fit_cfg = FitConfig { split_rows, folds: 5, n_lambdas: 10, ..Default::default() };
        let st = bench(&format!("map split={label}"), cfg, || {
            Driver::new(fit_cfg).compute_fold_stats(&data).unwrap().1.records
        });
        if base.is_nan() {
            base = st.mean_s;
        }
        t.row(vec![
            "A3 split size".into(),
            label.into(),
            fmt_secs(st.mean_s),
            sig(st.mean_s / base, 3),
        ]);
    }

    // A4: serial vs parallel CV phase
    let folds = {
        let mut fs: Vec<SuffStats> = (0..10).map(|_| SuffStats::new(p)).collect();
        for i in 0..data.n() {
            fs[i % 10].push(data.row(i), data.y[i]);
        }
        FoldStats::new(fs).unwrap()
    };
    let serial = bench("cv serial", cfg, || {
        cross_validate(&folds, Penalty::lasso(), &grid, CdSettings::default())
            .unwrap()
            .lambda_opt
    });
    let parallel = bench("cv parallel", cfg, || {
        cross_validate_parallel(
            &folds,
            Penalty::lasso(),
            &grid,
            CdSettings::default(),
            &EngineConfig::default(),
        )
        .unwrap()
        .lambda_opt
    });
    t.row(vec!["A4 CV phase".into(), "serial".into(), fmt_secs(serial.mean_s), "1.00".into()]);
    t.row(vec![
        "A4 CV phase".into(),
        "MapReduce job (paper §4)".into(),
        fmt_secs(parallel.mean_s),
        sig(parallel.mean_s / serial.mean_s, 3),
    ]);

    println!("## ablations (n={n}, p={p})\n");
    println!("{}", t.render());
    println!("\nratio > 1 means the ablated variant is slower than the shipped default.");
}
