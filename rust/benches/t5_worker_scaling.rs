//! cargo-bench entry for experiment t5 — regenerates the corresponding
//! EXPERIMENTS.md table/figure (T5: worker scaling (paper claim C1)).
//! Pass --quick (after --) to shrink the workload ~10x.

use plrmr::experiments::{self, ExpOptions};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExpOptions { quick, workers: 0 };
    match experiments::run("t5", opts) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("t5_worker_scaling failed: {e:#}");
            std::process::exit(1);
        }
    }
}
