//! cargo-bench entry for experiment t6 — regenerates the corresponding
//! EXPERIMENTS.md table (T6: fault tolerance of the one pass).
//! Pass --quick (after --) to shrink the workload ~10x.

use plrmr::experiments::{self, ExpOptions};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExpOptions { quick, workers: 0 };
    match experiments::run("t6", opts) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("t6_fault_tolerance failed: {e:#}");
            std::process::exit(1);
        }
    }
}
