//! cargo-bench entry for experiment t6 — regenerates the corresponding
//! EXPERIMENTS.md table (T6: fault tolerance of the one pass).
//! Pass --quick (after --) to shrink the workload ~10x.

use plrmr::experiments::{self, ExpOptions};

fn main() {
    // bench executables are not named `plrmr`, so point the supervisor at
    // the real CLI binary for the process-isolation section
    std::env::set_var("PLRMR_WORKER_BIN", env!("CARGO_BIN_EXE_plrmr"));
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExpOptions { quick, workers: 0 };
    match experiments::run("t6", opts) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("t6_fault_tolerance failed: {e:#}");
            std::process::exit(1);
        }
    }
}
