//! cargo-bench entry for experiment f3 — regenerates the corresponding
//! EXPERIMENTS.md table/figure (F3: the CV curve pre(lambda) (paper claim C3)).
//! Pass --quick (after --) to shrink the workload ~10x.

use plrmr::experiments::{self, ExpOptions};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExpOptions { quick, workers: 0 };
    match experiments::run("f3", opts) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("f3_cv_curve failed: {e:#}");
            std::process::exit(1);
        }
    }
}
