//! Packed-symmetric vs dense-square statistics (§Perf of EXPERIMENTS.md).
//!
//! The one-pass sufficient statistic is symmetric, so since the SymMat
//! refactor every O(p²) object on the fit path (M2, the standardized Gram,
//! fold complements) stores p(p+1)/2 doubles instead of p².  This bench
//! quantifies the three places that matters:
//!
//! * **merge** — the packed Chan merge vs an in-bench dense-square
//!   reference (the pre-refactor representation): half the doubles
//!   touched per combiner/reduce merge.
//! * **train complement** — `FoldStats::train_for` (alloc per call) vs
//!   `train_into` (one reused scratch): the CV phase's k-per-sweep path.
//! * **full CV sweep** — end-to-end λ-grid cross-validation wall-clock.
//!
//! It also prints the resident-memory arithmetic for the (k+1) fold
//! statistics and the engine's measured `JobMetrics::shuffle_bytes` for a
//! SuffStats job.
//!
//! Run: `cargo bench --bench gram_packed [-- --quick]`

use plrmr::bench::{bench, fmt_bytes, render, BenchConfig};
use plrmr::cv::{cross_validate, FoldStats};
use plrmr::mapreduce::{run_job, Emitter, EngineConfig, FoldAssigner, TaskCtx};
use plrmr::rng::Rng;
use plrmr::solver::path::lambda_grid;
use plrmr::solver::{CdSettings, Penalty};
use plrmr::stats::symm::tri_len;
use plrmr::stats::SuffStats;
use plrmr::util::table::{sig, Table};

/// The pre-refactor representation: a dense-square (d×d) centered scatter
/// with the same weighted Chan merge — the baseline the packed kernels are
/// timed against.  Values are arbitrary; merge cost is data-independent.
struct DenseStats {
    d: usize,
    w: f64,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl DenseStats {
    fn random(d: usize, w: f64, rng: &mut Rng) -> Self {
        DenseStats {
            d,
            w,
            mean: (0..d).map(|_| rng.normal()).collect(),
            m2: (0..d * d).map(|_| rng.normal().abs()).collect(),
        }
    }

    fn merge(&mut self, other: &DenseStats) {
        let d = self.d;
        let (m, n) = (self.w, other.w);
        let total = m + n;
        let w_other = n / total;
        let coef = m * n / total;
        let delta: Vec<f64> = (0..d).map(|i| other.mean[i] - self.mean[i]).collect();
        for i in 0..d {
            let ci = coef * delta[i];
            let row = &mut self.m2[i * d..(i + 1) * d];
            let orow = &other.m2[i * d..(i + 1) * d];
            for ((s, &o), &dj) in row.iter_mut().zip(orow).zip(&delta) {
                *s += o + ci * dj;
            }
        }
        for i in 0..d {
            self.mean[i] += delta[i] * w_other;
        }
        self.w = total;
    }
}

/// SuffStats chunk filled from a deterministic stream.
fn chunk(p: usize, rows: usize, seed: u64) -> SuffStats {
    let mut rng = Rng::seed_from(seed);
    let x: Vec<f64> = (0..rows * p).map(|_| rng.normal_ms(1.0, 2.0)).collect();
    let y: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
    let mut s = SuffStats::new(p);
    s.push_rows(&x, &y);
    s
}

fn fold_stats(p: usize, k: usize, rows_per_fold: usize, seed: u64) -> FoldStats {
    let folds: Vec<SuffStats> = (0..k)
        .map(|i| chunk(p, rows_per_fold, seed + i as u64))
        .collect();
    FoldStats::new(folds).expect("valid folds")
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let ps: &[usize] = if quick { &[16, 64] } else { &[64, 256, 1024] };
    let k = 10;

    println!("## gram_packed — packed-symmetric vs dense-square statistics\n");

    // --- resident-memory arithmetic ------------------------------------
    let mut mem = Table::new(vec![
        "p", "packed/stat", "dense/stat", "ratio", "(k+1) stats packed", "dense",
    ]);
    for &p in ps {
        let d = p + 1;
        let packed = 8 * (2 + d + tri_len(d));
        let dense = 8 * (2 + d + d * d);
        mem.row(vec![
            format!("{p}"),
            fmt_bytes(packed),
            fmt_bytes(dense),
            sig(dense as f64 / packed as f64, 3),
            fmt_bytes((k + 1) * packed),
            fmt_bytes((k + 1) * dense),
        ]);
    }
    println!("{}\n", mem.render());

    // --- merge / complement / CV timings -------------------------------
    let mut results = Vec::new();
    for &p in ps {
        let d = p + 1;
        let rows = 256.min(64 * 1024 / p.max(1)).max(32);

        // packed Chan merge (the shipping representation)
        let a = chunk(p, rows, 11);
        let b = chunk(p, rows, 13);
        results.push(bench(&format!("merge packed p={p}"), cfg, || {
            let mut acc = a.clone();
            acc.merge(&b);
            acc.count()
        }));

        // dense-square Chan merge (the pre-refactor representation)
        let mut rng = Rng::seed_from(17);
        let da = DenseStats::random(d, rows as f64, &mut rng);
        let db = DenseStats::random(d, rows as f64, &mut rng);
        results.push(bench(&format!("merge dense  p={p}"), cfg, || {
            let mut acc = DenseStats {
                d: da.d,
                w: da.w,
                mean: da.mean.clone(),
                m2: da.m2.clone(),
            };
            acc.merge(&db);
            acc.w
        }));

        // fold complement: fresh allocation vs reused scratch
        let folds = fold_stats(p, k, rows, 23);
        results.push(bench(&format!("train_for (alloc) p={p}"), cfg, || {
            let mut n = 0;
            for i in 0..k {
                n += folds.train_for(i).count();
            }
            n
        }));
        let mut scratch = SuffStats::new(p);
        results.push(bench(&format!("train_into (scratch) p={p}"), cfg, || {
            let mut n = 0;
            for i in 0..k {
                folds.train_into(i, &mut scratch);
                n += scratch.count();
            }
            n
        }));

        // full CV sweep on the packed path
        let cv_folds = fold_stats(p, 5, rows, 31);
        let grid = lambda_grid(cv_folds.total().quad_form().lambda_max(1.0), 6, 1e-2);
        results.push(bench(&format!("cv sweep (5 folds, 6 λ) p={p}"), cfg, || {
            cross_validate(&cv_folds, Penalty::lasso(), &grid, CdSettings::default())
                .unwrap()
                .opt_index
        }));
    }
    println!("{}\n", render(&results));

    // --- measured shuffle bytes of a SuffStats job ---------------------
    let p = if quick { 32 } else { 128 };
    let d = p + 1;
    let n_tasks = 8;
    let assigner = FoldAssigner::new(4, 7);
    let inputs: Vec<usize> = (0..n_tasks).collect();
    let out = run_job(
        &EngineConfig::with_workers(4),
        &inputs,
        |ctx: &TaskCtx, _t: &usize, em: &mut Emitter<usize, SuffStats>| {
            let mut rng = Rng::seed_from(0xFEED + ctx.task_id as u64);
            for r in 0..64usize {
                let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
                let fold = assigner.fold_of((ctx.task_id * 64 + r) as u64);
                em.upsert_with(fold, || SuffStats::new(p), |s| s.push(&x, 1.0));
            }
        },
    )
    .expect("stats job");
    let dense_equiv = out.metrics.shuffle_payloads * 4 * 8 * (2 + d + d * d);
    println!(
        "suffstats job p={p}: shuffle {} across {} payloads (dense-square equivalent ≈ {}, {}x)",
        fmt_bytes(out.metrics.shuffle_bytes),
        out.metrics.shuffle_payloads,
        fmt_bytes(dense_equiv),
        sig(dense_equiv as f64 / out.metrics.shuffle_bytes.max(1) as f64, 3),
    );
    println!(
        "\nNOTE: merge/complement rows compare equal-arithmetic kernels; the packed\n\
         rows touch p(p+1)/2 doubles where dense touches p² — the ~2× shows up\n\
         directly in resident fold statistics and engine shuffle volume."
    );
}
