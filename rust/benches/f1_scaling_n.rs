//! cargo-bench entry for experiment f1 — regenerates the corresponding
//! EXPERIMENTS.md table/figure (F1: scaling in n (paper claims C1/C5)).
//! Pass --quick (after --) to shrink the workload ~10x.

use plrmr::experiments::{self, ExpOptions};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExpOptions { quick, workers: 0 };
    match experiments::run("f1", opts) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("f1_scaling_n failed: {e:#}");
            std::process::exit(1);
        }
    }
}
