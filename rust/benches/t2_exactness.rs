//! cargo-bench entry for experiment t2 — regenerates the corresponding
//! EXPERIMENTS.md table/figure (T2: exactness vs serial oracle (paper claim C2)).
//! Pass --quick (after --) to shrink the workload ~10x.

use plrmr::experiments::{self, ExpOptions};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExpOptions { quick, workers: 0 };
    match experiments::run("t2", opts) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("t2_exactness failed: {e:#}");
            std::process::exit(1);
        }
    }
}
