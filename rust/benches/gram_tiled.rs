//! Tiled vs packed fold statistics (§Perf of EXPERIMENTS.md).
//!
//! PR 2's packed triangle halved the O(p²) statistic; this bench
//! quantifies what tiling it into `(fold, panel)` reduce keys
//! (`stats::tiles`) does to the three quantities that bind at large p:
//!
//! * **peak per-key payload** — the largest value the shuffle/merge tree
//!   ever holds: the whole packed triangle (~d²/2 doubles) untiled vs one
//!   row-block panel (≤ d·b doubles) tiled — arithmetic table at
//!   p ∈ {1024, 4096}, plus the engine-measured
//!   `JobMetrics::max_payload_bytes` for both paths.
//! * **total shuffle bytes** — tiling re-ships one O(d) header per panel;
//!   the table shows that overhead staying in the noise.
//! * **CV wall-clock** — the CV phase runs on the reassembled statistics,
//!   so tiling must cost ~nothing there; the `shard+assemble` row prices
//!   the reassembly itself against a full CV sweep.
//! * **sparse ingest** — nonzero-aware scatter end to end through the
//!   engine: map wall-clock, shuffle bytes and suppressed (all-zero)
//!   panels vs the dense path, folds asserted bit-identical.
//!
//! Exactness is asserted inline (tiled fold statistics == untiled, bit
//! for bit) — it is the contract, not a benchmark outcome.
//!
//! Run: `cargo bench --bench gram_tiled [-- --quick]`

use plrmr::bench::{bench, fmt_bytes, render, render_job_phases, BenchConfig};
use plrmr::config::FitConfig;
use plrmr::coordinator::Driver;
use plrmr::cv::{cross_validate, FoldStats};
use plrmr::data::synth::{generate, SynthSpec};
use plrmr::data::Dataset;
use plrmr::rng::Rng;
use plrmr::solver::path::lambda_grid;
use plrmr::solver::{CdSettings, Penalty};
use plrmr::stats::symm::tri_len;
use plrmr::stats::tiles::{assemble_stats, shard_stats, TileLayout};
use plrmr::stats::{Scatter, SuffStats};
use plrmr::util::json::Value;
use plrmr::util::table::{sig, Table};

/// SuffStats chunk filled from a deterministic stream.
fn chunk(p: usize, rows: usize, seed: u64) -> SuffStats {
    let mut rng = Rng::seed_from(seed);
    let x: Vec<f64> = (0..rows * p).map(|_| rng.normal_ms(1.0, 2.0)).collect();
    let y: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
    let mut s = SuffStats::new(p);
    s.push_rows(&x, &y);
    s
}

fn fold_stats(p: usize, k: usize, rows_per_fold: usize, seed: u64) -> FoldStats {
    let folds: Vec<SuffStats> = (0..k)
        .map(|i| chunk(p, rows_per_fold, seed + i as u64))
        .collect();
    FoldStats::new(folds).expect("valid folds")
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let key = std::mem::size_of::<(usize, usize)>();

    println!("## gram_tiled — (fold, panel)-keyed statistics vs one triangle per fold\n");

    // --- peak per-key payload arithmetic (exact, deterministic) ---------
    let ps: &[usize] = if quick { &[64, 128] } else { &[1024, 4096] };
    let mut t = Table::new(vec![
        "p", "block", "panels", "packed/key", "tiled max/key", "ratio", "header overhead",
    ]);
    for &p in ps {
        let d = p + 1;
        let packed_key = 8 + 8 * (2 + d + tri_len(d));
        for block in [64usize, 256] {
            let layout = TileLayout::new(d, block);
            let tiled_key = key + 8 * (2 + d + layout.max_panel_len());
            // tiling re-ships one (n, w, mean) header per extra panel
            let overhead = (layout.n_panels() - 1) * (key + 8 * (2 + d));
            t.row(vec![
                format!("{p}"),
                format!("{block}"),
                format!("{}", layout.n_panels()),
                fmt_bytes(packed_key),
                fmt_bytes(tiled_key),
                sig(packed_key as f64 / tiled_key as f64, 3),
                fmt_bytes(overhead),
            ]);
        }
    }
    println!("{}\n", t.render());

    // --- engine-measured payloads, untiled vs tiled ---------------------
    let p = if quick { 32 } else { 256 };
    let block = if quick { 8 } else { 64 };
    let data = generate(&SynthSpec::sparse_linear(4000, p, 0.2, 7));
    let base = FitConfig {
        folds: 5,
        n_lambdas: 8,
        workers: 4,
        split_rows: 500,
        ..Default::default()
    };
    let (f0, m0) = Driver::new(base).compute_fold_stats(&data).unwrap();
    let (f1, m1) = Driver::new(FitConfig { gram_block: block, ..base })
        .compute_fold_stats(&data)
        .unwrap();
    // exactness contract, not a benchmark artifact
    for i in 0..5 {
        assert_eq!(f0.fold(i), f1.fold(i), "tiled fold {i} drifted");
    }
    let mut m = Table::new(vec!["job", "shuffle bytes", "max key payload", "payloads"]);
    let tiled_name = format!("tiled b={block}");
    for (name, jm) in [("untiled", &m0), (tiled_name.as_str(), &m1)] {
        m.row(vec![
            name.to_string(),
            fmt_bytes(jm.shuffle_bytes),
            fmt_bytes(jm.max_payload_bytes),
            format!("{}", jm.shuffle_payloads),
        ]);
    }
    println!("measured stats job at p={p} (5 folds, 4 workers):\n{}\n", m.render());

    // --- sparse ingest through the engine: map wall-clock, shuffle bytes,
    //     suppressed panels ---------------------------------------------
    // End-to-end, so the numbers are honest: centering densifies every
    // *touched* column, so the win at i.i.d. row density is governed by the
    // chunk-level support union, not the per-row nonzero count (the raw
    // kernel bound lives in benches/micro.rs).  The structured row zeroes
    // half the columns dataset-wide — that is what turns whole panels into
    // O(d) zero markers (`skipped` column) and shrinks the shuffle.
    {
        let p_sp = if quick { 64 } else { 1024 };
        // block must divide the zeroed half-range below into whole panels
        let b_sp = if quick { 16 } else { 64 };
        let spcfg = FitConfig {
            folds: 5,
            workers: 4,
            split_rows: 500,
            gram_block: b_sp,
            ..Default::default()
        };
        let mut jobs = Vec::new();
        let mut run_pair = |label: &str, data: &Dataset| {
            let (fd, md) = Driver::new(spcfg).compute_fold_stats(data).unwrap();
            let (fs, ms) = Driver::new(spcfg.with_sparse(true)).compute_fold_stats(data).unwrap();
            // exactness contract, not a benchmark outcome
            for i in 0..5 {
                assert_eq!(fd.fold(i), fs.fold(i), "sparse fold {i} drifted ({label})");
            }
            jobs.push((format!("dense  {label}"), md));
            jobs.push((format!("sparse {label}"), ms));
        };
        for density in [1.0f64, 0.01, 0.001] {
            let spec = SynthSpec {
                x_density: density,
                ..SynthSpec::sparse_linear(4000, p_sp, 0.2, 7)
            };
            run_pair(&format!("nz={density}"), &generate(&spec));
        }
        // structured sparsity: columns p/2.. identically zero → the panels
        // covering them are suppressed end to end
        let src = generate(&SynthSpec::sparse_linear(4000, p_sp, 0.2, 9));
        let mut x = src.x.clone();
        for r in 0..src.n() {
            for j in p_sp / 2..p_sp {
                x[r * p_sp + j] = 0.0;
            }
        }
        run_pair("zero cols p/2..", &Dataset::new(p_sp, x, src.y.clone()));
        let (_, structured_sparse) = jobs.last().unwrap();
        assert!(
            structured_sparse.panels_skipped > 0,
            "structured zero columns must suppress whole panels"
        );
        assert!(
            structured_sparse.shuffle_bytes < jobs[jobs.len() - 2].1.shuffle_bytes,
            "suppressed panels must shrink the shuffle"
        );
        println!(
            "sparse vs dense ingest at p={p_sp}, b={b_sp} (5 folds, 4 workers;\n\
             folds asserted bit-identical per row pair):\n{}\n",
            render_job_phases(&jobs)
        );
    }

    // --- CV wall-clock + the cost of shard/assemble ---------------------
    let ps_cv: &[usize] = if quick { &[64, 128] } else { &[1024, 4096] };
    let cfg = if quick {
        BenchConfig::quick()
    } else {
        BenchConfig { warmup: 1, max_samples: 3, budget_s: 2.0 }
    };
    let cd = CdSettings { tol: 1e-6, max_sweeps: 500, active_set: true };
    let mut results = Vec::new();
    // tiled-solve column: peak resident statistic bytes, untiled vs tiled
    // QuadForm, for the same CV workload (wall-clock in the bench rows)
    let mut resident = Table::new(vec![
        "p", "k", "peak stat alloc (packed)", "peak (tiled b=64)", "ratio",
    ]);
    for &p in ps_cv {
        let k = if p >= 4096 { 3 } else { 5 };
        let fs = fold_stats(p, k, 48, 31);
        let grid = lambda_grid(fs.total().quad_form().lambda_max(1.0), 4, 1e-2);
        // the SAME doubles re-sliced into b=64 panels: the whole CV phase
        // (complements, Grams, CD) runs panel-native on this backing
        let fs_tiled = FoldStats::new(
            (0..k).map(|i| fs.fold(i).to_tiled(64)).collect::<Vec<_>>(),
        )
        .expect("valid tiled folds");
        // exactness contract, not a benchmark outcome: the tiled-solve CV
        // matrix is bit-identical to the packed one
        let cv_packed = cross_validate(&fs, Penalty::lasso(), &grid, cd).unwrap();
        let cv_tiled = cross_validate(&fs_tiled, Penalty::lasso(), &grid, cd).unwrap();
        assert_eq!(cv_packed.fold_err, cv_tiled.fold_err, "tiled CV drifted (p={p})");
        assert_eq!(cv_packed.lambda_opt, cv_tiled.lambda_opt);
        let packed_alloc = 8 * fs.max_alloc_doubles();
        let tiled_alloc = 8 * fs_tiled
            .max_alloc_doubles()
            .max(fs_tiled.total().quad_form().gram.max_alloc_doubles());
        resident.row(vec![
            format!("{p}"),
            format!("{k}"),
            fmt_bytes(packed_alloc),
            fmt_bytes(tiled_alloc),
            sig(packed_alloc as f64 / tiled_alloc as f64, 3),
        ]);
        results.push(bench(&format!("cv sweep packed ({k} folds, 4 λ) p={p}"), cfg, || {
            cross_validate(&fs, Penalty::lasso(), &grid, cd).unwrap().opt_index
        }));
        results.push(bench(&format!("cv sweep tiled b=64 ({k} folds, 4 λ) p={p}"), cfg, || {
            cross_validate(&fs_tiled, Penalty::lasso(), &grid, cd)
                .unwrap()
                .opt_index
        }));
        let layout = TileLayout::new(p + 1, 64);
        let total = fs.total().clone();
        results.push(bench(&format!("shard+assemble (b=64) p={p}"), cfg, || {
            let panels = shard_stats(&total, layout);
            assemble_stats(p, layout, &panels).unwrap().count()
        }));
    }
    println!(
        "peak resident statistic allocation, identical CV workload (largest\n\
         single buffer any fold statistic / Gram holds):\n{}\n",
        resident.render()
    );
    println!("{}\n", render(&results));

    // --- spillable panel store: resident peak & spill traffic vs budget --
    // (measured through the whole Driver fit: retire-mode reduce into the
    // store, store-streamed CV on the worker pool, final solve)
    let p_s = if quick { 32 } else { 256 };
    let b_s = if quick { 8 } else { 64 };
    let d_s = p_s + 1;
    let slayout = TileLayout::new(d_s, b_s);
    let one_panel = 8 * (2 + d_s + slayout.max_panel_len());
    let sdata = generate(&SynthSpec::sparse_linear(4000, p_s, 0.2, 7));
    let sbase = FitConfig {
        folds: 5,
        n_lambdas: 8,
        workers: 4,
        split_rows: 500,
        gram_block: b_s,
        ..Default::default()
    };
    let mut spill_t = Table::new(vec![
        "store budget",
        "prefetch",
        "resident peak",
        "spilled",
        "writes",
        "reads",
        "pf issued",
        "pf hit rate",
        "fit wall-clock",
    ]);
    let mut reference: Option<Vec<f64>> = None;
    for (label, budget, prefetch) in [
        ("unbounded (mem)", 0usize, true),
        ("8 panels", 8 * one_panel, false),
        ("8 panels", 8 * one_panel, true),
        ("1 panel", one_panel, false),
        ("1 panel", one_panel, true),
    ] {
        let cfg = FitConfig { store_budget_bytes: budget, ..sbase }.with_prefetch(prefetch);
        let t0 = std::time::Instant::now();
        let report = Driver::new(cfg).fit(&sdata).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        // exactness contract, not a benchmark outcome: neither the budget
        // nor the readahead may change a bit of the fit
        match &reference {
            None => reference = Some(report.model.beta.clone()),
            Some(beta) => {
                assert_eq!(&report.model.beta, beta, "budget/prefetch changed the fit")
            }
        }
        if budget > 0 {
            // exact admission: readahead never loosens the residency bound
            assert!(
                report.resident_stat_bytes_peak <= budget.max(one_panel),
                "resident {} over budget {budget}",
                report.resident_stat_bytes_peak
            );
        }
        let hit_rate = if report.prefetch_issued > 0 {
            sig(report.prefetch_hits as f64 / report.prefetch_issued as f64, 3)
        } else {
            "-".to_string()
        };
        spill_t.row(vec![
            label.to_string(),
            if prefetch { "on" } else { "off" }.to_string(),
            fmt_bytes(report.resident_stat_bytes_peak),
            fmt_bytes(report.spill_bytes),
            format!("{}", report.spill_writes),
            format!("{}", report.spill_reads),
            format!("{}", report.prefetch_issued),
            hit_rate,
            plrmr::util::timer::fmt_secs(dt),
        ]);
    }
    println!(
        "spillable panel store at p={p_s}, b={b_s} (5 folds, CV on the worker\n\
         pool; fit asserted bit-identical across budgets and prefetch on/off):\n{}\n",
        spill_t.render()
    );

    // --- machine-readable phase summary (--quick, the CI shape) ---------
    // One traced fit → BENCH_gram_tiled.json: per-phase duration stats and
    // skew from `trace::analyze`, plus the fit's own metrics JSON — the
    // regenerable evidence behind EXPERIMENTS.md §Observability.
    if quick {
        plrmr::trace::set_enabled(true);
        let report = Driver::new(sbase).fit(&sdata).unwrap();
        plrmr::trace::set_enabled(false);
        // observe-only contract: tracing may not change a bit of the fit
        if let Some(beta) = &reference {
            assert_eq!(&report.model.beta, beta, "tracing changed the fit");
        }
        let mut events = plrmr::trace::drain();
        plrmr::trace::canonicalize(&mut events);
        let analysis = plrmr::trace::analyze::analyze(&events);
        let mut root = std::collections::BTreeMap::new();
        root.insert("bench".to_string(), Value::Str("gram_tiled".to_string()));
        root.insert("trace".to_string(), analysis.to_json());
        root.insert("fit".to_string(), report.to_json());
        let path = "BENCH_gram_tiled.json";
        std::fs::write(path, Value::Obj(root).render()).expect("write bench json");
        println!(
            "wrote {path} (map skew {} across {} events)\n",
            sig(analysis.map_skew(), 3),
            analysis.events
        );
    }

    // arithmetic envelope at paper scale: what the leader must hold
    // resident, unbounded vs budgeted (5 folds + total, headers included)
    let mut env = Table::new(vec![
        "p",
        "block",
        "one panel",
        "resident ∞",
        "resident @8 panels",
        "resident @1 panel",
    ]);
    for &p in ps {
        let d = p + 1;
        for block in [64usize, 256] {
            let layout = TileLayout::new(d, block);
            let one = 8 * (2 + d + layout.max_panel_len());
            let per_fold = 8 * (layout.n_panels() * (2 + d) + tri_len(d));
            env.row(vec![
                format!("{p}"),
                format!("{block}"),
                fmt_bytes(one),
                fmt_bytes(6 * per_fold),
                fmt_bytes(8 * one),
                fmt_bytes(one),
            ]);
        }
    }
    println!(
        "leader-resident statistic envelope (5 folds + total):\n{}\n",
        env.render()
    );

    println!(
        "NOTE: the tiled and untiled paths produce bit-identical statistics,\n\
         CV matrices and models (asserted above and in tests/integration.rs);\n\
         tiling buys the per-key payload bound in the first table and the\n\
         resident-allocation bound above for the price of one replicated O(d)\n\
         header per extra panel.  With --store-budget the merged panels\n\
         retire into a spill store and the leader's resident statistics\n\
         follow the budget, not k·d²/2 — bit-identically (table above)."
    );
}
