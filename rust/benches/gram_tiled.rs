//! Tiled vs packed fold statistics (§Perf of EXPERIMENTS.md).
//!
//! PR 2's packed triangle halved the O(p²) statistic; this bench
//! quantifies what tiling it into `(fold, panel)` reduce keys
//! (`stats::tiles`) does to the three quantities that bind at large p:
//!
//! * **peak per-key payload** — the largest value the shuffle/merge tree
//!   ever holds: the whole packed triangle (~d²/2 doubles) untiled vs one
//!   row-block panel (≤ d·b doubles) tiled — arithmetic table at
//!   p ∈ {1024, 4096}, plus the engine-measured
//!   `JobMetrics::max_payload_bytes` for both paths.
//! * **total shuffle bytes** — tiling re-ships one O(d) header per panel;
//!   the table shows that overhead staying in the noise.
//! * **CV wall-clock** — the CV phase runs on the reassembled statistics,
//!   so tiling must cost ~nothing there; the `shard+assemble` row prices
//!   the reassembly itself against a full CV sweep.
//!
//! Exactness is asserted inline (tiled fold statistics == untiled, bit
//! for bit) — it is the contract, not a benchmark outcome.
//!
//! Run: `cargo bench --bench gram_tiled [-- --quick]`

use plrmr::bench::{bench, fmt_bytes, render, BenchConfig};
use plrmr::config::FitConfig;
use plrmr::coordinator::Driver;
use plrmr::cv::{cross_validate, FoldStats};
use plrmr::data::synth::{generate, SynthSpec};
use plrmr::rng::Rng;
use plrmr::solver::path::lambda_grid;
use plrmr::solver::{CdSettings, Penalty};
use plrmr::stats::symm::tri_len;
use plrmr::stats::tiles::{assemble_stats, shard_stats, TileLayout};
use plrmr::stats::SuffStats;
use plrmr::util::table::{sig, Table};

/// SuffStats chunk filled from a deterministic stream.
fn chunk(p: usize, rows: usize, seed: u64) -> SuffStats {
    let mut rng = Rng::seed_from(seed);
    let x: Vec<f64> = (0..rows * p).map(|_| rng.normal_ms(1.0, 2.0)).collect();
    let y: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
    let mut s = SuffStats::new(p);
    s.push_rows(&x, &y);
    s
}

fn fold_stats(p: usize, k: usize, rows_per_fold: usize, seed: u64) -> FoldStats {
    let folds: Vec<SuffStats> = (0..k)
        .map(|i| chunk(p, rows_per_fold, seed + i as u64))
        .collect();
    FoldStats::new(folds).expect("valid folds")
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let key = std::mem::size_of::<(usize, usize)>();

    println!("## gram_tiled — (fold, panel)-keyed statistics vs one triangle per fold\n");

    // --- peak per-key payload arithmetic (exact, deterministic) ---------
    let ps: &[usize] = if quick { &[64, 128] } else { &[1024, 4096] };
    let mut t = Table::new(vec![
        "p", "block", "panels", "packed/key", "tiled max/key", "ratio", "header overhead",
    ]);
    for &p in ps {
        let d = p + 1;
        let packed_key = 8 + 8 * (2 + d + tri_len(d));
        for block in [64usize, 256] {
            let layout = TileLayout::new(d, block);
            let tiled_key = key + 8 * (2 + d + layout.max_panel_len());
            // tiling re-ships one (n, w, mean) header per extra panel
            let overhead = (layout.n_panels() - 1) * (key + 8 * (2 + d));
            t.row(vec![
                format!("{p}"),
                format!("{block}"),
                format!("{}", layout.n_panels()),
                fmt_bytes(packed_key),
                fmt_bytes(tiled_key),
                sig(packed_key as f64 / tiled_key as f64, 3),
                fmt_bytes(overhead),
            ]);
        }
    }
    println!("{}\n", t.render());

    // --- engine-measured payloads, untiled vs tiled ---------------------
    let p = if quick { 32 } else { 256 };
    let block = if quick { 8 } else { 64 };
    let data = generate(&SynthSpec::sparse_linear(4000, p, 0.2, 7));
    let base = FitConfig {
        folds: 5,
        n_lambdas: 8,
        workers: 4,
        split_rows: 500,
        ..Default::default()
    };
    let (f0, m0) = Driver::new(base).compute_fold_stats(&data).unwrap();
    let (f1, m1) = Driver::new(FitConfig { gram_block: block, ..base })
        .compute_fold_stats(&data)
        .unwrap();
    // exactness contract, not a benchmark artifact
    for i in 0..5 {
        assert_eq!(f0.fold(i), f1.fold(i), "tiled fold {i} drifted");
    }
    let mut m = Table::new(vec!["job", "shuffle bytes", "max key payload", "payloads"]);
    let tiled_name = format!("tiled b={block}");
    for (name, jm) in [("untiled", &m0), (tiled_name.as_str(), &m1)] {
        m.row(vec![
            name.to_string(),
            fmt_bytes(jm.shuffle_bytes),
            fmt_bytes(jm.max_payload_bytes),
            format!("{}", jm.shuffle_payloads),
        ]);
    }
    println!("measured stats job at p={p} (5 folds, 4 workers):\n{}\n", m.render());

    // --- CV wall-clock + the cost of shard/assemble ---------------------
    let ps_cv: &[usize] = if quick { &[64, 128] } else { &[1024, 4096] };
    let cfg = if quick {
        BenchConfig::quick()
    } else {
        BenchConfig { warmup: 1, max_samples: 3, budget_s: 2.0 }
    };
    let cd = CdSettings { tol: 1e-6, max_sweeps: 500, active_set: true };
    let mut results = Vec::new();
    for &p in ps_cv {
        let k = if p >= 4096 { 3 } else { 5 };
        let fs = fold_stats(p, k, 48, 31);
        let grid = lambda_grid(fs.total().quad_form().lambda_max(1.0), 4, 1e-2);
        results.push(bench(&format!("cv sweep ({k} folds, 4 λ) p={p}"), cfg, || {
            cross_validate(&fs, Penalty::lasso(), &grid, cd).unwrap().opt_index
        }));
        let layout = TileLayout::new(p + 1, 64);
        let total = fs.total().clone();
        results.push(bench(&format!("shard+assemble (b=64) p={p}"), cfg, || {
            let panels = shard_stats(&total, layout);
            assemble_stats(p, layout, &panels).unwrap().count()
        }));
    }
    println!("{}\n", render(&results));

    println!(
        "NOTE: the tiled and untiled paths produce bit-identical statistics and\n\
         CV matrices (asserted above and in tests/integration.rs); tiling buys\n\
         the per-key payload bound in the first table for the price of one\n\
         replicated O(d) header per extra panel."
    );
}
